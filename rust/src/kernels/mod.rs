//! Pure-Rust quantized compute subsystem (DESIGN.md §11).
//!
//! The cost model (`quant::CostModel`) charges compute proportional to
//! k_w·k_a — but until this module existed the serving path dequantized
//! every packed tensor back to f32 and ran a strided scalar dot, so the
//! learned bit-widths saved disk bytes and zero compute. `kernels`
//! operates directly on the low-bit codes instead:
//!
//! * [`pack`] — u64 word-at-a-time bit-stream pack/unpack (the
//!   per-element loops survive only as property-test oracles);
//! * [`gemm`] — [`QuantGemm`] plans: codes unpacked once at load,
//!   centered, transposed to contiguous `[n_out][d]`, i8/i16 storage,
//!   exact i32 accumulation, scales folded into one epilogue multiply;
//! * [`activ`] — per-row on-the-fly activation quantization at the
//!   checkpoint's learned k_a, same s = 2^k − 1 grid as training;
//! * [`QuantMlp`] (here) — the multi-layer forward: fc stacks with
//!   ReLU, per-layer mixed k_w (each tensor's packed width) and k_a
//!   (checkpoint meta), row-parallel across std::thread workers.
//!
//! `serve::ReferenceBackend` is a thin adapter over [`QuantMlp`].

pub mod activ;
pub mod conv;
pub mod gemm;
pub mod pack;

pub use activ::{fake_quantize_row, quantize_row_centered, MAX_INT_ACT_BITS};
pub use conv::QuantConvNet;
pub use gemm::QuantGemm;

use crate::serve::packed::QuantizedCheckpoint;
use crate::util::json::Json;

/// One fc layer: a weight plan, bias, the activation width its *input*
/// is quantized at, and whether a ReLU follows it.
pub struct QuantLayer {
    pub name: String,
    pub gemm: QuantGemm,
    pub bias: Vec<f32>,
    pub k_a: u32,
    pub relu: bool,
}

/// A stack of [`QuantLayer`]s loaded from a packed checkpoint.
pub struct QuantMlp {
    pub layers: Vec<QuantLayer>,
    /// Input feature count of the first layer.
    pub input: usize,
    /// Output count of the last layer.
    pub classes: usize,
}

impl QuantMlp {
    /// Build from a packed checkpoint. Layer names come from the meta
    /// `mlp_layers` array (`["fc1", "fc2", …]`, ReLU between layers);
    /// a checkpoint without it serves the legacy single `fc` layer.
    /// Each layer `L` needs `L.w` (`[d_in, d_out]`) and optionally
    /// `L.b` (`[d_out]`). Activation widths: meta `k_a` globally,
    /// overridable per layer via a `layer_k_a` object (`{"fc1": 8}`);
    /// k_w is per-tensor by construction (each `PackedTensor` carries
    /// its own bit-width), so mixed-precision stacks need no extra meta.
    pub fn from_packed(q: &QuantizedCheckpoint) -> anyhow::Result<QuantMlp> {
        let names: Vec<String> = q
            .meta_layer_names("mlp_layers")?
            .unwrap_or_else(|| vec!["fc".to_string()]);
        let global_k_a =
            q.meta.get("k_a").and_then(Json::as_f64).unwrap_or(32.0) as u32;
        let per_layer = q.meta.get("layer_k_a");
        let last = names.len() - 1;
        let mut layers = Vec::with_capacity(names.len());
        for (li, name) in names.iter().enumerate() {
            let wt = q
                .get(&format!("{name}.w"))
                .ok_or_else(|| anyhow::anyhow!("packed checkpoint lacks {name}.w"))?;
            let k_a = per_layer
                .and_then(|m| m.get(name))
                .and_then(Json::as_f64)
                .map(|v| v as u32)
                .unwrap_or(global_k_a);
            anyhow::ensure!(k_a >= 1, "{name}: k_a must be >= 1");
            let gemm = QuantGemm::from_packed(wt, k_a)
                .map_err(|e| anyhow::anyhow!("{name}.w: {e}"))?;
            let bias = match q.get(&format!("{name}.b")) {
                Some(bt) => {
                    anyhow::ensure!(
                        bt.shape == vec![gemm.n_out],
                        "{name}.b shape {:?} != [{}]",
                        bt.shape,
                        gemm.n_out
                    );
                    bt.dequantize().data
                }
                None => vec![0.0; gemm.n_out],
            };
            layers.push(QuantLayer {
                name: name.clone(),
                gemm,
                bias,
                k_a,
                relu: li != last,
            });
        }
        for pair in layers.windows(2) {
            anyhow::ensure!(
                pair[0].gemm.n_out == pair[1].gemm.d,
                "layer chain mismatch: {}.w outputs {} but {}.w expects {}",
                pair[0].name,
                pair[0].gemm.n_out,
                pair[1].name,
                pair[1].gemm.d
            );
        }
        let input = layers[0].gemm.d;
        let classes = layers[layers.len() - 1].gemm.n_out;
        Ok(QuantMlp { layers, input, classes })
    }

    /// Logits for `rows` stacked input rows (`x.len() == rows·input`),
    /// row-parallel across `threads` std::thread workers (≤ 1 runs
    /// inline). Integer layers quantize their input rows on the fly;
    /// f32-fallback layers fake-quantize when k_a < 24 so the learned
    /// activation width is honoured either way. Per-row activation
    /// scales make results independent of batch composition: a row
    /// computes bit-identically at batch 1 and inside a full batch.
    pub fn forward(&self, x: &[f32], rows: usize, threads: usize) -> Vec<f32> {
        assert_eq!(x.len(), rows * self.input, "bad input length");
        let mut cur = x.to_vec();
        for layer in &self.layers {
            let d = layer.gemm.d;
            let n_out = layer.gemm.n_out;
            let mut next = vec![0.0f32; rows * n_out];
            if layer.gemm.is_integer() {
                let mut qa = vec![0i16; rows * d];
                let mut steps = vec![0.0f32; rows];
                for r in 0..rows {
                    steps[r] = activ::quantize_row_centered(
                        &cur[r * d..(r + 1) * d],
                        layer.k_a,
                        &mut qa[r * d..(r + 1) * d],
                    );
                }
                run_row_chunks(
                    threads,
                    rows,
                    n_out,
                    &mut next,
                    &|r0: usize, r1: usize, out: &mut [f32]| {
                        layer.gemm.forward_quant(
                            &qa[r0 * d..r1 * d],
                            &steps[r0..r1],
                            r1 - r0,
                            &layer.bias,
                            out,
                        );
                    },
                );
            } else {
                if layer.k_a < 24 {
                    for r in 0..rows {
                        activ::fake_quantize_row(&mut cur[r * d..(r + 1) * d], layer.k_a);
                    }
                }
                let xin = &cur;
                run_row_chunks(
                    threads,
                    rows,
                    n_out,
                    &mut next,
                    &|r0: usize, r1: usize, out: &mut [f32]| {
                        layer.gemm.forward_f32(
                            &xin[r0 * d..r1 * d],
                            r1 - r0,
                            &layer.bias,
                            out,
                        );
                    },
                );
            }
            if layer.relu {
                for v in next.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            cur = next;
        }
        cur
    }

    /// Argmax class per row (ties break to the lowest class id, the
    /// same rule the pre-kernels serving loop used).
    pub fn classify(&self, x: &[f32], rows: usize, threads: usize) -> Vec<usize> {
        let logits = self.forward(x, rows, threads);
        (0..rows)
            .map(|r| argmax(&logits[r * self.classes..(r + 1) * self.classes]))
            .collect()
    }
}

pub(crate) fn argmax(scores: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_score = f32::NEG_INFINITY;
    for (i, &s) in scores.iter().enumerate() {
        if s > best_score {
            best_score = s;
            best = i;
        }
    }
    best
}

/// Split `rows` into contiguous chunks and run `f(r0, r1, out_chunk)`
/// on up to `threads` scoped std::threads (rayon-free: the offline
/// crate universe has no dependencies, DESIGN.md §3). `threads ≤ 1`
/// runs inline. Chunking is by whole rows, so with the kernels'
/// order-independent integer accumulation the thread count never
/// changes results.
fn run_row_chunks<F>(threads: usize, rows: usize, n_out: usize, out: &mut [f32], f: &F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let t = threads.max(1).min(rows.max(1));
    if t <= 1 {
        f(0, rows, out);
        return;
    }
    let chunk = (rows + t - 1) / t;
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(chunk * n_out).enumerate() {
            let r0 = ci * chunk;
            let r1 = (r0 + chunk).min(rows);
            s.spawn(move || f(r0, r1, out_chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::packed::PackedTensor;
    use crate::tensor::checkpoint::Checkpoint;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_tensor(shape: Vec<usize>, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.normal() * 0.2).collect())
    }

    /// A legacy-style single-layer packed checkpoint (`fc.w`/`fc.b`).
    fn single_layer_packed(d: usize, classes: usize, bits: u32, k_a: f64) -> QuantizedCheckpoint {
        let mut ck = Checkpoint::new(Json::obj(vec![("k_a", Json::num(k_a))]));
        ck.push("fc.w", random_tensor(vec![d, classes], 21));
        ck.push("fc.b", random_tensor(vec![classes], 22));
        QuantizedCheckpoint::from_checkpoint(&ck, bits, |n| n.ends_with(".w"))
    }

    #[test]
    fn legacy_single_layer_f32_path_matches_old_strided_oracle() {
        // k_a = 32 (identity): the f32 plan must reproduce the
        // pre-kernels serving math — dequantized weights, strided
        // layout, ascending-index accumulation — bit for bit.
        let (d, classes) = (48usize, 10usize);
        let q = single_layer_packed(d, classes, 4, 32.0);
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert_eq!(mlp.layers.len(), 1);
        assert!(!mlp.layers[0].gemm.is_integer());
        assert!(!mlp.layers[0].relu);
        let w = q.get("fc.w").unwrap().dequantize().data;
        let b = q.get("fc.b").unwrap().dequantize().data;
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..3 * d).map(|_| rng.normal()).collect();
        let logits = mlp.forward(&x, 3, 1);
        for r in 0..3 {
            for cls in 0..classes {
                // the old ReferenceBackend::classify_one inner loop
                let mut score = b[cls];
                for i in 0..d {
                    score += x[r * d + i] * w[i * classes + cls];
                }
                assert_eq!(logits[r * classes + cls].to_bits(), score.to_bits());
            }
        }
    }

    #[test]
    fn two_layer_mixed_precision_chain() {
        // fc1 at 3 bits, fc2 at 8 bits, per-layer k_a override — the
        // per-tensor `bits` field carries mixed k_w with no extra meta.
        let (d, h, classes) = (24usize, 12usize, 5usize);
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(8.0)),
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
            (
                "layer_k_a",
                Json::obj(vec![("fc2", Json::num(6.0))]),
            ),
        ]));
        q.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![d, h], 1), 3));
        q.push("fc1.b", PackedTensor::raw(&random_tensor(vec![h], 2)));
        q.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![h, classes], 3), 8));
        q.push("fc2.b", PackedTensor::raw(&random_tensor(vec![classes], 4)));
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert_eq!(mlp.input, d);
        assert_eq!(mlp.classes, classes);
        assert_eq!(mlp.layers[0].gemm.bits, 3);
        assert_eq!(mlp.layers[1].gemm.bits, 8);
        assert_eq!(mlp.layers[0].k_a, 8);
        assert_eq!(mlp.layers[1].k_a, 6);
        assert!(mlp.layers[0].relu && !mlp.layers[1].relu);
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..4 * d).map(|_| rng.normal()).collect();
        let preds = mlp.classify(&x, 4, 1);
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < classes));
    }

    #[test]
    fn thread_count_never_changes_results() {
        let (d, h, classes) = (64usize, 32usize, 10usize);
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(8.0)),
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
        ]));
        q.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![d, h], 31), 4));
        q.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![h, classes], 32), 4));
        let mlp = QuantMlp::from_packed(&q).unwrap();
        let mut rng = Rng::new(33);
        let rows = 13usize; // deliberately not divisible by thread counts
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let base = mlp.forward(&x, rows, 1);
        for threads in [2usize, 3, 4, 8, 64] {
            let got = mlp.forward(&x, rows, threads);
            assert_eq!(base.len(), got.len());
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn batch_composition_does_not_change_a_row() {
        // per-row activation scales: row 3 of a 8-batch == the same
        // image at batch 1, bitwise
        let q = single_layer_packed(32, 7, 4, 6.0);
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert!(mlp.layers[0].gemm.is_integer());
        let mut rng = Rng::new(44);
        let x: Vec<f32> = (0..8 * 32).map(|_| rng.normal()).collect();
        let batch = mlp.forward(&x, 8, 2);
        let solo = mlp.forward(&x[3 * 32..4 * 32], 1, 1);
        for (a, b) in batch[3 * 7..4 * 7].iter().zip(&solo) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn missing_and_mismatched_tensors_error() {
        let q = QuantizedCheckpoint::new(Json::obj(vec![(
            "mlp_layers",
            Json::Arr(vec![Json::str("fc1")]),
        )]));
        assert!(QuantMlp::from_packed(&q).is_err());
        // chain mismatch: fc1 outputs 12, fc2 expects 13
        let mut q2 = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(8.0)),
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
        ]));
        q2.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![6, 12], 1), 4));
        q2.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![13, 3], 2), 4));
        assert!(QuantMlp::from_packed(&q2).is_err());
    }
}

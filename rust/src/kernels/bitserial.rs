//! Bit-sliced popcount GEMM (DESIGN.md §14): inner-loop work ∝ k_w·k_a.
//!
//! The dense integer plans (§11) execute the same i8/i16 multiply at
//! k = 2 as at k = 8 — the learned bit-widths save cache bytes but the
//! instruction count is flat in k, while the cost model (and the paper's
//! hardware model) charge compute ∝ k_w·k_a. This module makes that
//! proportionality physical. Centered codes q = 2c − s are *decomposed
//! by binary digit*: the raw codes c ∈ [0, s] of one weight output row
//! (and, on the fly, of one activation row) are scattered into k
//! bit planes of u64 words, and the exact integer dot falls out of pure
//! AND + popcount over those planes via the centering identity
//!
//! ```text
//!   Σᵢ q_aᵢ·q_wᵢ = Σᵢ (2c_aᵢ − s_a)(2c_wᵢ − s_w)
//!               = 4·P − 2·s_w·A − 2·s_a·W + d·s_a·s_w
//!   P = Σ_{j<k_a} Σ_{l<k_w} 2^{j+l} · popcount(a_plane_j & w_plane_l)
//!   A = Σᵢ c_aᵢ   (per activation row, folded out during slicing)
//!   W = Σᵢ c_wᵢ   (per weight row, precomputed at plan build)
//! ```
//!
//! so one AND+popcount word consumes **64 elements of one plane pair**
//! and the inner loop runs exactly k_w·k_a plane pairs: W2·A2 costs 4
//! word-ops per 64 elements where W4·A4 costs 16 — serving throughput
//! finally ratchets as the controller drives bits down. Every quantity
//! is an exact integer (tail bits past d are zero in both operands and
//! contribute nothing; the constant term uses the true d), so the
//! result equals the dense i8/i16 accumulator *bit for bit* and all
//! §11 guarantees — order independence, batch/thread invariance —
//! carry over unchanged. The property tests pin bitserial against the
//! dense path and against a scalar i64 oracle at every width pair.
//!
//! Popcount runs through one of three backends picked once at plan
//! build by runtime CPU detection: AVX2 (Mula nibble-LUT, 4 words per
//! step), the `popcnt` instruction, or the portable software fallback —
//! results are identical by construction (pinned by a test that runs
//! every available backend on the same planes).

use crate::quant::code_levels;

use super::activ::raw_code;
use super::gemm::OUT_TILE;
use super::pack;
use super::{force_portable, grab, KernelIsa, Scratch, SplitMut};

/// Largest k_w·k_a product for which [`super::QuantGemm`] auto-selects
/// the bitserial plan (`PlanChoice::Auto`). The crossover is where
/// k_w·k_a popcount pairs per 64 elements stop beating 64 dense
/// multiply-adds — re-derived on the bench sweep (`benches/kernels.rs`,
/// bitserial-vs-i8 rows) after the dense path gained AVX2 + tiling
/// (§16): against the *scalar* dense loop the crossover sat near 9
/// (W3·A3 and W2·A4 still won), but `_mm256_madd_epi16` retires 16
/// dense MACs per instruction, so only the very small products stay
/// ahead — W1·A1..W1·A4/W4·A1 and W2·A2 keep a clear margin, W3·A3 and
/// W2·A4 fall behind the vectorized dense kernel. 4 keeps exactly the
/// still-winning region on the popcount planes. The heuristic tracks
/// the vectorized common case on purpose (plans must pick the same
/// engine on every host — serving results are host-independent either
/// way, this is only a speed call). Forced construction via
/// `PlanChoice::Bitserial` ignores this (the bench sweeps k ∈ 1..=4).
pub const BITSERIAL_MAX_PRODUCT: u32 = 4;

/// Runtime popcount-backend pick ([`KernelIsa`]), the pattern the dense
/// dispatch mirrors: AVX2 Mula LUT when available, the `popcnt`
/// instruction next, portable fallback — with `ADAQAT_FORCE_PORTABLE`
/// read fresh each detection so one process can build portable and
/// native plans back to back (bench A/B, CI matrix).
fn detect_popcount() -> KernelIsa {
    // Miri interprets MIR and has no SIMD/popcnt intrinsics — pin the
    // portable backend so the kernel suites run under `cargo miri test`.
    if cfg!(miri) || force_portable() {
        return KernelIsa::Portable;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelIsa::Avx2;
        }
        if is_x86_feature_detected!("popcnt") {
            return KernelIsa::Popcnt;
        }
    }
    KernelIsa::Portable
}

/// The backend a bitserial plan built right now would run — the serve
/// startup banner ([`super::isa_summary`]) reports it.
pub fn detected_popcount_isa() -> KernelIsa {
    detect_popcount()
}

/// Bit-sliced weight planes for one GEMM: built once at checkpoint load
/// from the raw codes, driven per batch with on-the-fly activation
/// slicing into a [`Scratch`] arena.
pub struct BitserialGemm {
    d: usize,
    n_out: usize,
    k_a: u32,
    s_a: i32,
    s_w: i32,
    /// Words per plane: ⌈d/64⌉.
    words: usize,
    /// Weight planes, row-major `[n_out][k_w][words]`.
    planes: Vec<u64>,
    /// Σ c_w per output row (the W term of the centering identity).
    wsum: Vec<i64>,
    /// The constant term d·s_a·s_w.
    base: i64,
    k_w: u32,
    imp: KernelIsa,
}

impl BitserialGemm {
    /// Whether `PlanChoice::Auto` should pick bitserial at this width
    /// pair (the dense integer path must already be admissible).
    pub fn preferred(k_w: u32, k_a: u32) -> bool {
        k_w * k_a <= BITSERIAL_MAX_PRODUCT
    }

    /// Build planes from raw codes in the checkpoint's `[d, n_out]`
    /// row-major layout (the same `unpack_codes` output the dense plans
    /// center and transpose). Caller guarantees `integer_bound_ok`.
    pub fn from_codes(codes: &[u32], d: usize, n_out: usize, k_w: u32, k_a: u32) -> BitserialGemm {
        assert_eq!(codes.len(), d * n_out);
        let words = (d + 63) / 64;
        let per_out = k_w as usize * words;
        let mut planes = vec![0u64; n_out * per_out];
        let mut wsum = vec![0i64; n_out];
        for o in 0..n_out {
            wsum[o] = pack::codes_to_bitplanes(
                codes,
                o,
                n_out,
                d,
                k_w,
                &mut planes[o * per_out..(o + 1) * per_out],
            ) as i64;
        }
        let s_a = code_levels(k_a) as i32;
        let s_w = code_levels(k_w) as i32;
        BitserialGemm {
            d,
            n_out,
            k_a,
            s_a,
            s_w,
            words,
            planes,
            wsum,
            base: d as i64 * s_a as i64 * s_w as i64,
            k_w,
            imp: detect_popcount(),
        }
    }

    /// The popcount backend this plan dispatches to.
    pub(crate) fn isa(&self) -> KernelIsa {
        self.imp
    }

    /// Activation-plane words one batch row needs (k_a·⌈d/64⌉) — how
    /// callers size the staging buffer for [`slice_rows`].
    ///
    /// [`slice_rows`]: BitserialGemm::slice_rows
    pub(crate) fn plane_words_per_row(&self) -> usize {
        self.k_a as usize * self.words
    }

    /// Slice rows `r0..r1`'s centered codes into activation bit-planes —
    /// the batch-amortized half of the forward (§16): the pooled path
    /// runs this once per batch (row-parallel across lanes), then every
    /// column tile sweeps the shared planes instead of re-slicing its
    /// rows. `planes`/`asum` are chunk-relative: row `r` lands at
    /// `(r − r0)·plane_words_per_row()`.
    ///
    /// An all-zero row is the quantizer's Δ = 0 sentinel: its centered
    /// codes are all 0, which is *off* the parity grid, so the
    /// centering identity does not apply — its exact integer dot is
    /// simply 0 (what the dense path computes), forced in the sweep.
    /// The row's planes are left unwritten (stale arena contents); the
    /// sweep's acc short-circuit never reads them.
    pub(crate) fn slice_rows(
        &self,
        qa: &[i16],
        step_a: &[f32],
        r0: usize,
        r1: usize,
        planes: &mut [u64],
        asum: &mut [i64],
    ) {
        let d = self.d;
        let per_row = self.plane_words_per_row();
        debug_assert_eq!(planes.len(), (r1 - r0) * per_row);
        debug_assert_eq!(asum.len(), r1 - r0);
        for r in r0..r1 {
            let i = r - r0;
            if step_a[r] != 0.0 {
                asum[i] = slice_row(
                    &qa[r * d..(r + 1) * d],
                    self.s_a,
                    self.k_a,
                    &mut planes[i * per_row..(i + 1) * per_row],
                );
            } else {
                asum[i] = 0;
            }
        }
    }

    /// Sweep weight planes `o0..o1` against pre-sliced activation
    /// planes for rows `r0..r1` — the tile unit the pooled forward
    /// distributes. Unlike [`slice_rows`]'s chunks, `planes`/`asum`
    /// here index the *full batch* (row `r` at
    /// `r·plane_words_per_row()`): column tiles share one slicing pass.
    /// `dscale[r]` is the hoisted Δ_a[r]·Δ_w epilogue constant.
    /// Liveness keys on `step_a[r] != 0.0`, *not* `dscale[r] == 0.0` —
    /// a zero-scale weight tensor zeroes every dscale while its rows'
    /// planes are live, and the epilogue must still fold the true acc
    /// so the bits match the dense path exactly. Tiles cover disjoint
    /// (r, o) cells of `out`: race-free.
    ///
    /// [`slice_rows`]: BitserialGemm::slice_rows
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn sweep_cols(
        &self,
        planes: &[u64],
        asum: &[i64],
        step_a: &[f32],
        dscale: &[f64],
        r0: usize,
        r1: usize,
        o0: usize,
        o1: usize,
        gain: Option<&[f32]>,
        bias: &[f32],
        out: &SplitMut<f32>,
    ) {
        let words = self.words;
        let ka = self.k_a as usize;
        let kw = self.k_w as usize;
        let per_row = ka * words;
        let per_out = kw * words;
        for ot0 in (o0..o1).step_by(OUT_TILE) {
            let ot1 = (ot0 + OUT_TILE).min(o1);
            for r in r0..r1 {
                let ap = &planes[r * per_row..(r + 1) * per_row];
                let da = dscale[r];
                let live = step_a[r] != 0.0;
                for o in ot0..ot1 {
                    let acc = if live {
                        let wp = &self.planes[o * per_out..(o + 1) * per_out];
                        let p = weighted_and_popcount(ap, wp, words, ka, kw, self.imp);
                        4 * p - 2 * (self.s_w as i64) * asum[r]
                            - 2 * (self.s_a as i64) * self.wsum[o]
                            + self.base
                    } else {
                        0
                    };
                    let scale = match gain {
                        Some(g) => da * g[o] as f64,
                        None => da,
                    };
                    // SAFETY: tiles cover disjoint (r, o) cells.
                    unsafe {
                        out.write(r * self.n_out + o, (acc as f64 * scale) as f32 + bias[o])
                    };
                }
            }
        }
    }

    /// The exact-integer forward over centered activation codes —
    /// identical arithmetic contract to the dense tile kernel (`sw` is
    /// Δ_w as f64; `gain = None` reproduces the unscaled epilogue):
    /// `out[r,o] = (acc·Δ_a[r]·Δ_w[·gain[o]]) + bias[o]` with acc the
    /// exact Σ q_a·q_w. A composition of [`slice_rows`] (into the
    /// scratch arena — no allocation once warm) and one full-range
    /// [`sweep_cols`]; the pooled forward calls the two halves directly
    /// to amortize slicing across column tiles.
    ///
    /// [`slice_rows`]: BitserialGemm::slice_rows
    /// [`sweep_cols`]: BitserialGemm::sweep_cols
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        qa: &[i16],
        step_a: &[f32],
        rows: usize,
        sw: f64,
        gain: Option<&[f32]>,
        bias: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let per_row = self.plane_words_per_row();
        let Scratch { planes: aplanes, asum, dscale, grow_events, .. } = scratch;
        grab(aplanes, rows * per_row, grow_events);
        grab(asum, rows, grow_events);
        grab(dscale, rows, grow_events);
        for r in 0..rows {
            dscale[r] = step_a[r] as f64 * sw;
        }
        self.slice_rows(qa, step_a, 0, rows, aplanes, asum);
        let split = SplitMut::new(out);
        self.sweep_cols(aplanes, asum, step_a, dscale, 0, rows, 0, self.n_out, gain, bias, &split);
    }
}

/// Slice one centered activation row into `bits` planes of raw codes
/// (c = (q + s)/2, see [`raw_code`]); returns Σc. Writes every word of
/// `planes` (tail bits zero), so the buffer needs no pre-clearing.
fn slice_row(q: &[i16], s_a: i32, bits: u32, planes: &mut [u64]) -> i64 {
    let d = q.len();
    let words = (d + 63) / 64;
    debug_assert_eq!(planes.len(), bits as usize * words);
    let ka = bits as usize;
    let mut sum = 0i64;
    // k_a ≤ 15 always holds (the integer path's i16 bound)
    let mut regs = [0u64; 16];
    for w in 0..words {
        regs[..ka].fill(0);
        let i0 = w * 64;
        let i1 = (i0 + 64).min(d);
        for (b, &qi) in q[i0..i1].iter().enumerate() {
            let c = raw_code(qi, s_a) as u64;
            sum += c as i64;
            for (j, reg) in regs[..ka].iter_mut().enumerate() {
                *reg |= ((c >> j) & 1) << b;
            }
        }
        for (j, &reg) in regs[..ka].iter().enumerate() {
            planes[j * words + w] = reg;
        }
    }
    sum
}

/// P = Σ_{j,l} 2^{j+l}·popcount(a_j & w_l) over `ka × kw` plane pairs,
/// dispatched to the backend detected at plan build. All backends
/// return identical integers (pinned by `popcount_backends_agree`).
fn weighted_and_popcount(
    a: &[u64],
    w: &[u64],
    words: usize,
    ka: usize,
    kw: usize,
    imp: KernelIsa,
) -> i64 {
    match imp {
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Popcnt => {
            // SAFETY: plans only carry Popcnt when detection confirmed
            // it at plan build.
            unsafe { weighted_pairs_popcnt(a, w, words, ka, kw) }
        }
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => {
            // SAFETY: plans only carry Avx2 when detection confirmed
            // it at plan build.
            unsafe { weighted_pairs_avx2(a, w, words, ka, kw) }
        }
        _ => weighted_pairs(a, w, words, ka, kw),
    }
}

/// Portable pair loop. `#[inline(always)]` so the `popcnt`-enabled
/// wrapper compiles this body with the hardware instruction.
#[inline(always)]
fn weighted_pairs(a: &[u64], w: &[u64], words: usize, ka: usize, kw: usize) -> i64 {
    let mut p = 0i64;
    for j in 0..ka {
        let aj = &a[j * words..(j + 1) * words];
        for l in 0..kw {
            let wl = &w[l * words..(l + 1) * words];
            let mut cnt = 0u32;
            for (&x, &y) in aj.iter().zip(wl) {
                cnt += (x & y).count_ones();
            }
            p += (cnt as i64) << (j + l);
        }
    }
    p
}

/// [`weighted_pairs`] compiled with the hardware `popcnt` instruction
/// (one word per op instead of the ~12-op software fold).
///
/// # Safety
/// Caller must have verified `popcnt` support (detection at plan build).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn weighted_pairs_popcnt(a: &[u64], w: &[u64], words: usize, ka: usize, kw: usize) -> i64 {
    weighted_pairs(a, w, words, ka, kw)
}

/// AVX2 pair loop: Mula's nibble-LUT popcount (`vpshufb` on both
/// nibbles, byte sums folded through `vpsadbw`), 4 words of AND per
/// step, scalar remainder for the ≤ 3 tail words.
///
/// # Safety
/// Caller must have verified AVX2 support (detection at plan build).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn weighted_pairs_avx2(a: &[u64], w: &[u64], words: usize, ka: usize, kw: usize) -> i64 {
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi64, _mm256_add_epi8, _mm256_and_si256, _mm256_loadu_si256,
        _mm256_sad_epu8, _mm256_set1_epi8, _mm256_setr_epi8, _mm256_setzero_si256,
        _mm256_shuffle_epi8, _mm256_srli_epi16, _mm256_storeu_si256,
    };
    // SAFETY: every 256-bit load covers 4 in-bounds u64 words
    // (t < words/4) with no alignment requirement (`loadu`), the
    // `lanes` store writes exactly the 32 bytes it owns, and AVX2 is
    // guaranteed by this function's contract.
    unsafe {
        #[rustfmt::skip]
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let chunks = words / 4;
        let mut p = 0i64;
        for j in 0..ka {
            let aj = &a[j * words..(j + 1) * words];
            for l in 0..kw {
                let wl = &w[l * words..(l + 1) * words];
                let mut acc = zero;
                for t in 0..chunks {
                    let va = _mm256_loadu_si256(aj.as_ptr().add(4 * t) as *const __m256i);
                    let vb = _mm256_loadu_si256(wl.as_ptr().add(4 * t) as *const __m256i);
                    let v = _mm256_and_si256(va, vb);
                    let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(v, low));
                    let nib = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
                    let hi = _mm256_shuffle_epi8(lut, nib);
                    acc = _mm256_add_epi64(acc, _mm256_sad_epu8(_mm256_add_epi8(lo, hi), zero));
                }
                let mut lanes = [0u64; 4];
                _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
                let mut cnt = lanes[0] + lanes[1] + lanes[2] + lanes[3];
                for t in 4 * chunks..words {
                    cnt += (aj[t] & wl[t]).count_ones() as u64;
                }
                p += (cnt as i64) << (j + l);
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::activ::quantize_row_centered;
    use crate::kernels::gemm::{PlanChoice, PlanKind, QuantGemm};
    use crate::serve::packed::PackedTensor;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_tensor(shape: Vec<usize>, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.normal() * 0.2).collect())
    }

    fn quantized_rows(x: &[f32], rows: usize, d: usize, k_a: u32) -> (Vec<i16>, Vec<f32>) {
        let mut qa = vec![0i16; rows * d];
        let mut steps = vec![0.0f32; rows];
        for r in 0..rows {
            steps[r] =
                quantize_row_centered(&x[r * d..(r + 1) * d], k_a, &mut qa[r * d..(r + 1) * d]);
        }
        (qa, steps)
    }

    /// Every available popcount backend must return the same weighted
    /// sum on the same planes — this is the test that pins the AVX2
    /// intrinsics against the portable loop.
    #[test]
    fn popcount_backends_agree() {
        let mut rng = Rng::new(91);
        for (ka, kw, words) in [(1usize, 1usize, 1usize), (2, 2, 5), (3, 3, 7), (4, 2, 48)] {
            let a: Vec<u64> = (0..ka * words).map(|_| rng.next_u64()).collect();
            let w: Vec<u64> = (0..kw * words).map(|_| rng.next_u64()).collect();
            let want = weighted_pairs(&a, &w, words, ka, kw);
            #[cfg(target_arch = "x86_64")]
            {
                if is_x86_feature_detected!("popcnt") {
                    // SAFETY: popcnt support just verified above.
                    let got = unsafe { weighted_pairs_popcnt(&a, &w, words, ka, kw) };
                    assert_eq!(got, want, "popcnt backend ka={ka} kw={kw} words={words}");
                }
                if is_x86_feature_detected!("avx2") {
                    // SAFETY: AVX2 support just verified above.
                    let got = unsafe { weighted_pairs_avx2(&a, &w, words, ka, kw) };
                    assert_eq!(got, want, "avx2 backend ka={ka} kw={kw} words={words}");
                }
            }
        }
    }

    #[test]
    fn slice_row_scatters_raw_codes_and_sums() {
        let mut rng = Rng::new(17);
        for bits in [1u32, 2, 3, 4] {
            let d = 131usize; // tail word with 3 live bits
            let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let mut qa = vec![0i16; d];
            quantize_row_centered(&x, bits, &mut qa);
            let s = code_levels(bits) as i32;
            let words = (d + 63) / 64;
            let mut planes = vec![u64::MAX; bits as usize * words];
            let sum = slice_row(&qa, s, bits, &mut planes);
            let mut want_sum = 0i64;
            for (i, &q) in qa.iter().enumerate() {
                let c = raw_code(q, s);
                want_sum += c as i64;
                for j in 0..bits as usize {
                    assert_eq!(
                        (planes[j * words + i / 64] >> (i % 64)) & 1,
                        ((c >> j) & 1) as u64,
                        "bits={bits} i={i} j={j}"
                    );
                }
            }
            assert_eq!(sum, want_sum, "bits={bits}");
            for j in 0..bits as usize {
                for i in d..words * 64 {
                    assert_eq!(
                        (planes[j * words + i / 64] >> (i % 64)) & 1,
                        0,
                        "bits={bits}: tail bit {i} set"
                    );
                }
            }
        }
    }

    /// Bitserial vs the dense i8 path, bit for bit, across every width
    /// pair k_w, k_a ∈ 1..=4 and reduction lengths that hit whole-word,
    /// one-word and tail-word shapes — arbitrary scales, with and
    /// without the per-channel gain epilogue.
    #[test]
    fn bitserial_matches_dense_integer_path_bitwise() {
        let mut rng = Rng::new(5);
        for &d in &[63usize, 64, 67, 131, 200] {
            for k_w in 1..=4u32 {
                for k_a in 1..=4u32 {
                    let n_out = 9usize;
                    let rows = 3usize;
                    let wt = PackedTensor::quantize(&random_tensor(vec![d, n_out], d as u64), k_w);
                    let dense =
                        QuantGemm::from_packed_with(&wt, k_a, PlanChoice::DenseInt).unwrap();
                    let bits =
                        QuantGemm::from_packed_with(&wt, k_a, PlanChoice::Bitserial).unwrap();
                    assert_eq!(dense.plan_kind(), PlanKind::Int8);
                    assert_eq!(bits.plan_kind(), PlanKind::Bitserial);
                    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
                    let (qa, steps) = quantized_rows(&x, rows, d, k_a);
                    let bias: Vec<f32> = (0..n_out).map(|_| rng.normal() * 0.1).collect();
                    let gain: Vec<f32> = (0..n_out).map(|_| 0.5 + rng.uniform()).collect();

                    let mut want = vec![0.0f32; rows * n_out];
                    dense.forward_quant(&qa, &steps, rows, &bias, &mut want);
                    let mut got = vec![0.0f32; rows * n_out];
                    bits.forward_quant(&qa, &steps, rows, &bias, &mut got);
                    for (a, b) in want.iter().zip(&got) {
                        assert_eq!(a.to_bits(), b.to_bits(), "d={d} k_w={k_w} k_a={k_a}");
                    }

                    dense.forward_quant_scaled(&qa, &steps, rows, &gain, &bias, &mut want);
                    bits.forward_quant_scaled(&qa, &steps, rows, &gain, &bias, &mut got);
                    for (a, b) in want.iter().zip(&got) {
                        assert_eq!(a.to_bits(), b.to_bits(), "scaled d={d} k_w={k_w} k_a={k_a}");
                    }
                }
            }
        }
    }

    /// Bitserial vs a from-scratch scalar oracle: per-element payload
    /// unpack, centered i64 dot, the same f64 epilogue — no planes, no
    /// popcounts, no shared code with the kernel under test.
    #[test]
    fn bitserial_matches_scalar_i64_oracle() {
        let mut rng = Rng::new(23);
        for k in 1..=4u32 {
            let d = 131usize;
            let n_out = 7usize;
            let rows = 4usize;
            let wt = PackedTensor::quantize(&random_tensor(vec![d, n_out], 300 + k as u64), k);
            let gemm = QuantGemm::from_packed_with(&wt, k, PlanChoice::Bitserial).unwrap();
            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            let (qa, steps) = quantized_rows(&x, rows, d, k);
            let bias = vec![0.5f32; n_out];
            let mut got = vec![0.0f32; rows * n_out];
            gemm.forward_quant(&qa, &steps, rows, &bias, &mut got);

            let s_i = code_levels(k) as i64;
            let sw = if wt.scale > 0.0 { wt.scale / s_i as f32 } else { 0.0 };
            for r in 0..rows {
                for o in 0..n_out {
                    let mut acc = 0i64;
                    for i in 0..d {
                        let c =
                            pack::read_bits_scalar(&wt.payload, (i * n_out + o) * k as usize, k)
                                as i64;
                        acc += qa[r * d + i] as i64 * (2 * c - s_i);
                    }
                    let want = (acc as f64 * (steps[r] as f64 * sw as f64)) as f32 + bias[o];
                    assert_eq!(got[r * n_out + o].to_bits(), want.to_bits(), "k={k} r={r} o={o}");
                }
            }
        }
    }

    #[test]
    fn zero_rows_and_zero_scale_stay_exact() {
        // an all-zero activation row has Δ = 0 and all-zero codes; the
        // identity's constant terms must still cancel to bias exactly
        let d = 70usize;
        let n_out = 3usize;
        let wt = PackedTensor::quantize(&random_tensor(vec![d, n_out], 9), 2);
        let gemm = QuantGemm::from_packed_with(&wt, 2, PlanChoice::Bitserial).unwrap();
        let x = vec![0.0f32; d];
        let (qa, steps) = quantized_rows(&x, 1, d, 2);
        assert_eq!(steps[0], 0.0);
        let mut out = vec![0.0f32; n_out];
        gemm.forward_quant(&qa, &steps, 1, &[1.0, 2.0, 3.0], &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);

        // zero-scale weights: every code is 0, Δ_w = 0 ⇒ logits = bias
        let wz = PackedTensor::quantize(&Tensor::zeros(vec![d, n_out]), 2);
        assert_eq!(wz.scale, 0.0);
        let gz = QuantGemm::from_packed_with(&wz, 2, PlanChoice::Bitserial).unwrap();
        let xs = vec![1.0f32; d];
        let (qa, steps) = quantized_rows(&xs, 1, d, 2);
        let mut out = vec![0.0f32; n_out];
        gz.forward_quant(&qa, &steps, 1, &[4.0, 5.0, 6.0], &mut out);
        assert_eq!(out, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn preferred_follows_the_product_threshold() {
        assert!(BitserialGemm::preferred(1, 1));
        assert!(BitserialGemm::preferred(2, 2));
        assert!(BitserialGemm::preferred(1, 4));
        assert!(BitserialGemm::preferred(4, 1));
        // products the SIMD dense path now wins (crossover 9 → 4, §16)
        assert!(!BitserialGemm::preferred(3, 3));
        assert!(!BitserialGemm::preferred(2, 4));
        assert!(!BitserialGemm::preferred(1, 8));
        assert!(!BitserialGemm::preferred(2, 5));
        assert!(!BitserialGemm::preferred(4, 4));
        assert!(!BitserialGemm::preferred(2, 8));
    }

    /// The batch-amortized path — chunked [`BitserialGemm::slice_rows`]
    /// calls + column-tiled [`BitserialGemm::sweep_cols`] over shared
    /// planes — must equal `run` over the whole batch AND `run` called
    /// per row, bitwise, including a Δ = 0 sentinel row mid-batch.
    #[test]
    fn batch_amortized_slicing_matches_per_row_runs_bitwise() {
        use crate::kernels::SplitMut;
        let mut rng = Rng::new(53);
        for (k_w, k_a) in [(1u32, 1u32), (2, 2), (1, 4)] {
            let d = 131usize;
            let n_out = 40usize;
            let rows = 5usize;
            let wt =
                PackedTensor::quantize(&random_tensor(vec![d, n_out], 400 + k_w as u64), k_w);
            let gemm = QuantGemm::from_packed_with(&wt, k_a, PlanChoice::Bitserial).unwrap();
            let mut x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            x[2 * d..3 * d].fill(0.0); // Δ = 0 sentinel row mid-batch
            let (qa, steps) = quantized_rows(&x, rows, d, k_a);
            assert_eq!(steps[2], 0.0);
            let bias: Vec<f32> = (0..n_out).map(|_| rng.normal() * 0.1).collect();

            // reference: the whole batch through run()
            let mut want = vec![0.0f32; rows * n_out];
            gemm.forward_quant(&qa, &steps, rows, &bias, &mut want);

            // the pre-amortization shape: one run() per row
            let mut per_row_out = vec![0.0f32; rows * n_out];
            for r in 0..rows {
                gemm.forward_quant(
                    &qa[r * d..(r + 1) * d],
                    &steps[r..r + 1],
                    1,
                    &bias,
                    &mut per_row_out[r * n_out..(r + 1) * n_out],
                );
            }

            // batch-amortized: chunked slicing (exercises r0 > 0), then
            // column tiles sweeping the shared planes
            let bits = gemm.bitserial().expect("bitserial plan");
            let per = bits.plane_words_per_row();
            let mut planes = vec![0u64; rows * per];
            let mut asum = vec![0i64; rows];
            bits.slice_rows(&qa, &steps, 0, 2, &mut planes[..2 * per], &mut asum[..2]);
            bits.slice_rows(&qa, &steps, 2, rows, &mut planes[2 * per..], &mut asum[2..]);
            let sw = gemm.step_w as f64;
            let dscale: Vec<f64> = steps.iter().map(|&s| s as f64 * sw).collect();
            let mut got = vec![0.0f32; rows * n_out];
            let split = SplitMut::new(&mut got);
            for (o0, o1) in [(0usize, 13usize), (13, 30), (30, n_out)] {
                bits.sweep_cols(
                    &planes, &asum, &steps, &dscale, 0, rows, o0, o1, None, &bias, &split,
                );
            }
            drop(split);
            for i in 0..rows * n_out {
                assert_eq!(
                    want[i].to_bits(),
                    per_row_out[i].to_bits(),
                    "per-row k=({k_w},{k_a}) cell {i}"
                );
                assert_eq!(
                    want[i].to_bits(),
                    got[i].to_bits(),
                    "presliced k=({k_w},{k_a}) cell {i}"
                );
            }
        }
    }
}

//! Offline API-compatible stub of the `log` facade crate.
//!
//! The offline crate universe (DESIGN.md §3) has no registry access, so
//! the subset of `log` this repo actually uses is vendored here: the
//! five level macros, the `Log` trait, `set_logger`/`set_max_level`,
//! and the `Level`/`LevelFilter` ordering (including the cross-type
//! comparison `Level <= LevelFilter` the stderr backend relies on).
//! Swapping in the real crate is a one-line Cargo.toml change; nothing
//! in-tree depends on stub-only behavior.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record. Discriminants order `Error` (most
/// severe, lowest) through `Trace` so `level <= filter` reads naturally.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `f.pad` so width/alignment format specs (`{:5}`) work.
        f.pad(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a record (the stub only carries the level).
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: level + preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend (mirrors `log::Log`).
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, args: fmt::Arguments<'_>) {
    if (level as usize) <= MAX_LEVEL.load(Ordering::Relaxed) {
        if let Some(logger) = LOGGER.get() {
            let record = Record { metadata: Metadata { level }, args };
            if logger.enabled(record.metadata()) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Error, ::std::format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Warn, ::std::format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Info, ::std::format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Debug, ::std::format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Trace, ::std::format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
        assert_eq!(format!("{}", Level::Error), "ERROR");
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}

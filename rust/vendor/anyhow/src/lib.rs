//! Offline API-compatible stub of `anyhow`.
//!
//! The offline crate universe (DESIGN.md §3) has no registry access, so
//! the subset of `anyhow` this repo uses is vendored here: a
//! string-backed [`Error`] convertible from any `std::error::Error`
//! (which makes `?` work everywhere), the [`Result`] alias, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Context chaining is elided —
//! call sites already build full messages with `anyhow!`.

use std::fmt;

/// A string-backed error. Deliberately does NOT implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// below stays coherent (same trick as the real crate).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(::std::format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("boom"));
        assert!(format!("{e:#}").contains("boom"));
        assert!(format!("{e:?}").contains("boom"));
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e = anyhow!("value {x} bad: {}", 7);
        assert_eq!(e.to_string(), "value 3 bad: 7");
        let from_string: Error = anyhow!(String::from("plain"));
        assert_eq!(from_string.to_string(), "plain");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Ok(n)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(12).unwrap_err().to_string().contains("12"));
        assert!(check(5).unwrap_err().to_string().contains("five"));
    }

    #[test]
    fn bare_ensure_names_condition() {
        fn f() -> Result<()> {
            let a = 1;
            ensure!(a == 2);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("a == 2"));
    }
}

//! Offline API-compatible stub of the `xla` PJRT bindings.
//!
//! The real crate links libxla_extension (PJRT CPU client + HLO parser),
//! which cannot be fetched in the offline build environment (DESIGN.md
//! §3). This stub mirrors the exact API surface `crate::runtime` uses so
//! the whole workspace — coordinator, controller, data pipeline, serving
//! subsystem — builds and tests without PJRT. Host-side [`Literal`]
//! construction and inspection are real (they back unit tests); only
//! graph *execution* is unavailable: [`PjRtLoadedExecutable::execute`]
//! returns [`Error`] with a clear message. Swapping in the real bindings
//! is a one-line Cargo.toml change; integration tests and benches detect
//! missing artifacts/PJRT and skip rather than fail.

use std::fmt;
use std::path::Path;
use std::rc::Rc;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn element_size(self) -> usize {
        4
    }
}

/// Sealed-ish marker for element types [`Literal`] can view as.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-resident array (or tuple of arrays).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal {
            ty: ElementType::F32,
            shape: vec![],
            bytes: v.to_le_bytes().to_vec(),
            tuple: None,
        }
    }

    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = shape.iter().product();
        if numel * ty.element_size() != data.len() {
            return Err(Error(format!(
                "literal shape {shape:?} wants {} bytes, got {}",
                numel * ty.element_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), bytes: data.to_vec(), tuple: None })
    }

    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, shape: vec![], bytes: vec![], tuple: Some(elements) }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!("literal is {:?}, not {:?}", self.ty, T::TY)));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple.ok_or_else(|| Error("literal is not a tuple".to_string()))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        let mut v = self.to_tuple()?;
        if v.len() != 2 {
            return Err(Error(format!("tuple has {} elements, wanted 2", v.len())));
        }
        let b = v.pop().unwrap();
        let a = v.pop().unwrap();
        Ok((a, b))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.into_iter().next().ok_or_else(|| Error("empty literal".to_string()))
    }
}

/// Parsed HLO module. The stub just retains the text.
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("{path:?}: {e}")))?;
        if !text.starts_with("HloModule") {
            return Err(Error(format!("{path:?}: not HLO text")));
        }
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// PJRT client handle. `Rc`-based so it is `!Send`, matching the real
/// bindings (each thread must own its own client).
#[derive(Clone)]
pub struct PjRtClient {
    _marker: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _marker: Rc::new(()) })
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { _marker: Rc::new(()) })
    }
}

pub struct PjRtLoadedExecutable {
    _marker: Rc<()>,
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error("offline xla stub: no buffers exist".to_string()))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(
            "offline xla stub: PJRT execution unavailable — link the real \
             `xla` bindings (see DESIGN.md §3) to run compiled graphs"
                .to_string(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), xs);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[4],
            &[0u8; 8]
        )
        .is_err());
    }

    #[test]
    fn tuples_unpack() {
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]);
        let (a, b) = t.to_tuple2().unwrap();
        assert_eq!(a.get_first_element::<f32>().unwrap(), 1.0);
        assert_eq!(b.get_first_element::<f32>().unwrap(), 2.0);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn execution_is_stubbed() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let exe = client
            .compile(&XlaComputation { _text: String::new() })
            .unwrap();
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("offline xla stub"));
    }
}

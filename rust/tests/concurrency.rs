//! Concurrency stress suite (DESIGN.md §17).
//!
//! These tests widen the schedule space around the repo's shared-state
//! hot spots — worker-pool generations, the bounded request queue, the
//! trace ring, the metrics registry — with seeded yield-jitter, and
//! assert conservation/bit-stability invariants that any interleaving
//! must preserve. They run in tier-1 (`cargo test`), and the CI
//! `analysis` job re-runs them under ThreadSanitizer
//! (`scripts/analyze.sh`), where the jitter turns each assertion into
//! a race probe.
//!
//! Policy note: this file deliberately uses `Ordering::SeqCst` for its
//! own bookkeeping — the Relaxed allow-list (unsafe_audit.conf) covers
//! production counter modules only.

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use adaqat::kernels::{QuantMlp, WorkerPool};
use adaqat::obs::{Registry, RequestTrace, TraceRing};
use adaqat::serve::packed::{PackedTensor, QuantizedCheckpoint};
use adaqat::serve::queue::{Pop, PushError, RequestQueue, ServeRequest};
use adaqat::tensor::Tensor;
use adaqat::util::json::Json;
use adaqat::util::rng::Rng;

/// Yield a seeded number of times (0..=max) to perturb the schedule.
fn jitter(rng: &mut Rng, max: usize) {
    for _ in 0..rng.below(max + 1) {
        std::thread::yield_now();
    }
}

/// Every pool generation must run every lane exactly once, no matter
/// how the lanes interleave — the fan-out counter and the lane bitmask
/// are conserved across 200 jittered generations.
#[test]
fn pool_fan_out_conserves_lanes_under_jitter() {
    let pool = WorkerPool::new(4);
    for gen in 0..200u64 {
        let hits = AtomicU64::new(0);
        let mask = AtomicU64::new(0);
        pool.run(|wid, _s| {
            let mut rng = Rng::new(0xFA11_0000 ^ (gen << 8) ^ wid as u64);
            jitter(&mut rng, 6);
            hits.fetch_add(1, Ordering::SeqCst);
            mask.fetch_or(1 << wid, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4, "generation {gen}");
        assert_eq!(mask.load(Ordering::SeqCst), 0b1111, "generation {gen}");
    }
}

/// Seeded job panics on rotating lanes (including the caller lane,
/// which poisons the main scratch mutex) must never wedge the pool:
/// every following generation still fans out to all lanes.
#[test]
fn pool_survives_rotating_job_panics() {
    let pool = WorkerPool::new(4);
    let hits = AtomicU64::new(0);
    let mut clean_runs = 0u64;
    for round in 0..24u64 {
        if round % 6 == 3 {
            let victim = (round / 6) as usize % 4;
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run(|wid, _s| {
                    if wid == victim {
                        panic!("seeded job panic (lane {wid})");
                    }
                    std::thread::yield_now();
                });
            }));
            assert!(r.is_err(), "round {round}: seeded panic must surface");
        } else {
            pool.run(|wid, _s| {
                let mut rng = Rng::new(0x9015_0000 ^ (round << 8) ^ wid as u64);
                jitter(&mut rng, 4);
                hits.fetch_add(1, Ordering::SeqCst);
            });
            clean_runs += 1;
        }
    }
    assert_eq!(hits.load(Ordering::SeqCst), clean_runs * 4, "pool lost lanes after panics");
}

fn request(id: u64, resp: &mpsc::Sender<adaqat::serve::ServeResponse>) -> ServeRequest {
    ServeRequest {
        id,
        pixels: Vec::new(),
        enqueued: Instant::now(),
        deadline: None,
        resp: resp.clone(),
    }
}

/// Conservation across backpressure: with 4 producers racing a
/// mid-stream close, every single request is either popped once or
/// counted in exactly one shed counter — nothing duplicated, nothing
/// lost.
#[test]
fn queue_sheds_conserve_every_request() {
    const PRODUCERS: u64 = 4;
    const PER_PRODUCER: u64 = 250;
    let reg = Registry::new();
    let q = RequestQueue::with_obs(64, &reg);
    let producers_done = Arc::new(AtomicBool::new(false));

    let consumer = {
        let q = Arc::clone(&q);
        let producers_done = Arc::clone(&producers_done);
        std::thread::spawn(move || {
            let mut rng = Rng::new(0xC0DE);
            let mut ids = HashSet::new();
            loop {
                match q.pop(Duration::from_millis(5)) {
                    Pop::Item(req) => {
                        assert!(ids.insert(req.id), "request {} delivered twice", req.id);
                        jitter(&mut rng, 3);
                        if ids.len() == 300 {
                            q.close();
                        }
                    }
                    Pop::TimedOut => {
                        if producers_done.load(Ordering::SeqCst) {
                            q.close();
                        }
                    }
                    Pop::Closed => return ids,
                }
            }
        })
    };

    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = Arc::clone(&q);
        handles.push(std::thread::spawn(move || {
            let (tx, _rx) = mpsc::channel();
            let mut rng = Rng::new(0x9E0D ^ p);
            let mut accepted = 0u64;
            for i in 0..PER_PRODUCER {
                jitter(&mut rng, 2);
                if q.push(request(p * PER_PRODUCER + i, &tx)).is_ok() {
                    accepted += 1;
                }
            }
            accepted
        }));
    }
    let accepted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    producers_done.store(true, Ordering::SeqCst);
    let ids = consumer.join().unwrap();

    let (shed_full, shed_closed) = q.shed_counts();
    let total = PRODUCERS * PER_PRODUCER;
    assert_eq!(accepted, ids.len() as u64, "accepted pushes must all be popped");
    assert_eq!(
        ids.len() as u64 + shed_full + shed_closed,
        total,
        "popped + shed(full) + shed(closed) must conserve every push"
    );
    assert_eq!(q.len(), 0, "queue must be drained");

    // the closed path, deterministically: one more push after close
    let (tx, _rx) = mpsc::channel();
    assert_eq!(q.push(request(total, &tx)), Err(PushError::Closed));
    assert_eq!(q.shed_counts().1, shed_closed + 1);
}

/// Concurrent wraparound: 8 threads hammer a capacity-64 ring with 500
/// pushes each. The total never loses a push, retention is exactly the
/// capacity, and the retained traces are distinct pushed values.
#[test]
fn trace_ring_concurrent_wraparound_is_bounded() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 500;
    let ring = Arc::new(TraceRing::new(64));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let ring = Arc::clone(&ring);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x7ACE ^ t);
            for i in 0..PER_THREAD {
                let seq = t * PER_THREAD + i;
                ring.push(RequestTrace {
                    id: seq,
                    enqueue_us: seq,
                    batch_us: seq + 1,
                    compute_done_us: seq + 2,
                    reply_us: seq + 3,
                    rows: 1,
                    ok: true,
                });
                jitter(&mut rng, 2);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(ring.total(), THREADS * PER_THREAD);
    let snap = ring.snapshot();
    assert_eq!(snap.len(), 64, "retention must equal capacity after wraparound");
    let mut seen = HashSet::new();
    for tr in &snap {
        assert!(tr.id < THREADS * PER_THREAD);
        assert_eq!(tr.reply_us, tr.id + 3, "trace fields must not tear");
        assert!(seen.insert(tr.id), "trace {} retained twice", tr.id);
    }
}

/// Concurrent get-or-register on the same series must hand every
/// thread the same underlying cell (sums conserve), and a same-name/
/// different-type collision must stay a warn-once no-op, not a panic.
#[test]
fn registry_registration_races_conserve_counts() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 200;
    let reg = Arc::new(Registry::new());
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let reg = Arc::clone(&reg);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x2E6 ^ t as u64);
            let label = (t % 2).to_string();
            for _ in 0..PER_THREAD {
                // four threads share each label: the same cell must be
                // returned on every lookup for the sums to conserve
                reg.counter("conc_hits_total", &[("half", label.as_str())]).inc();
                jitter(&mut rng, 2);
            }
            // type-collision path: half the threads re-request the
            // counter's name as a gauge — warn-once, detached handle
            if t % 2 == 1 {
                reg.gauge("conc_hits_total", &[("half", label.as_str())]).set(1.0);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let per_label = (THREADS as u64 / 2) * PER_THREAD;
    for half in ["0", "1"] {
        let c = reg.counter("conc_hits_total", &[("half", half)]);
        assert_eq!(c.get(), per_label, "label {half} lost increments");
    }
}

fn stress_mlp() -> QuantMlp {
    let (d, h, classes) = (96usize, 200usize, 40usize);
    let mut q = QuantizedCheckpoint::new(Json::obj(vec![
        ("k_a", Json::num(8.0)),
        ("mlp_layers", Json::Arr(vec![Json::str("fc1"), Json::str("fc2")])),
        // fc2 at k_w=1, k_a=4: product 4 rides the popcount planes
        ("layer_k_a", Json::obj(vec![("fc2", Json::num(4.0))])),
    ]));
    let mut rng = Rng::new(4021);
    let wn = |shape: Vec<usize>, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.normal() * 0.2).collect())
    };
    q.push("fc1.w", PackedTensor::quantize(&wn(vec![d, h], &mut rng), 4));
    q.push("fc2.w", PackedTensor::quantize(&wn(vec![h, classes], &mut rng), 1));
    QuantMlp::from_packed(&q).unwrap()
}

/// Bit-exactness under contention: four threads drive the same
/// `QuantMlp` through one shared `WorkerPool` (dense + bitserial
/// layers, staging arenas, SplitMut carves) — every result must stay
/// bit-identical to the single-threaded forward.
#[test]
fn shared_pool_forward_stays_bit_identical_under_contention() {
    let mlp = stress_mlp();
    let pool = WorkerPool::new(4);
    let rows = 5usize;
    let mut rng = Rng::new(77);
    let x: Vec<f32> = (0..rows * 96).map(|_| rng.normal()).collect();
    let baseline = mlp.forward(&x, rows, 1);
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let (mlp, pool, x, baseline) = (&mlp, &pool, &x, &baseline);
            s.spawn(move || {
                let mut rng = Rng::new(0xB17 ^ t);
                for _ in 0..25 {
                    jitter(&mut rng, 3);
                    let got = mlp.forward_pooled(x, rows, pool);
                    assert_eq!(got.len(), baseline.len());
                    for (a, b) in baseline.iter().zip(&got) {
                        assert_eq!(a.to_bits(), b.to_bits(), "thread {t} diverged");
                    }
                }
            });
        }
    });
}

/// Deterministic fault-injection scenarios (DESIGN.md §19). Compiled
/// and run only with the `failpoints` feature:
/// `cargo test --features failpoints --test concurrency` (verify.sh and
/// the CI TSan stage both do). Each scenario proves the conservation
/// identity — every submitted request lands in exactly one of
/// {answered, shed, overloaded, deadline-expired} — while faults fire.
#[cfg(feature = "failpoints")]
mod chaos {
    use super::*;
    use adaqat::serve::engine::SubmitError;
    use adaqat::serve::{Backend, Engine, EngineConfig, ServeError, Server};
    use adaqat::util::failpoint::{self, Action};
    use std::sync::Mutex;

    /// The failpoint registry is process-global, so chaos scenarios are
    /// serialized and each starts *and ends* disarmed (the guard clears
    /// on drop even when the test panics).
    static CHAOS: Mutex<()> = Mutex::new(());

    struct Armed {
        _lock: std::sync::MutexGuard<'static, ()>,
    }

    impl Drop for Armed {
        fn drop(&mut self) {
            failpoint::clear();
        }
    }

    fn armed() -> Armed {
        let lock = CHAOS.lock().unwrap_or_else(|p| p.into_inner());
        failpoint::clear();
        Armed { _lock: lock }
    }

    /// Fixed-delay 4-wide stub backend: chaos behavior comes from the
    /// failpoints, not from kernel timing.
    struct ChaosBackend {
        delay: Duration,
    }

    impl Backend for ChaosBackend {
        fn input_shape(&self) -> (usize, usize, usize) {
            (2, 2, 1)
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn num_classes(&self) -> usize {
            10
        }
        fn infer(&self, x: &Tensor) -> anyhow::Result<Vec<usize>> {
            std::thread::sleep(self.delay);
            Ok(vec![0; x.shape[0]])
        }
    }

    fn chaos_engine(cfg: EngineConfig, reg: &Registry) -> Arc<Engine> {
        Engine::start_with_obs(
            cfg,
            |_| {
                Ok(Box::new(ChaosBackend { delay: Duration::from_millis(2) })
                    as Box<dyn Backend>)
            },
            reg,
        )
        .unwrap()
    }

    /// Batcher stalls + mixed deadlines + admission control, 4 racing
    /// submitters: ground-truth tallies, per-request answers, and the
    /// observable counters must all close the conservation identity
    /// exactly.
    #[test]
    fn conservation_is_exact_under_stalls_and_mixed_deadlines() {
        let _armed = armed();
        failpoint::configure("batcher_stall", Action::Sleep(10));
        let reg = Registry::new();
        let engine = chaos_engine(
            EngineConfig {
                workers: 2,
                queue_capacity: 16,
                max_delay: Duration::from_millis(2),
                max_wait: Some(Duration::from_millis(40)),
                ..EngineConfig::default()
            },
            &reg,
        );
        let numel = engine.input_numel();
        const THREADS: u64 = 4;
        const PER: u64 = 100;
        let (tx, rx) = mpsc::channel();
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let engine = Arc::clone(&engine);
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xCA05 ^ t);
                // [accepted, overloaded, dl_admission, full, closed]
                let mut tally = [0u64; 5];
                for i in 0..PER {
                    jitter(&mut rng, 2);
                    let deadline_ms = match i % 4 {
                        0 => None,         // never expires
                        1 => Some(30_000), // generous
                        2 => Some(15),     // may expire in-queue
                        _ => Some(0),      // dead on arrival
                    };
                    match engine.submit_with_deadline(
                        t * PER + i,
                        vec![0.0; numel],
                        deadline_ms,
                        tx.clone(),
                    ) {
                        Ok(()) => tally[0] += 1,
                        Err(SubmitError::Overloaded { retry_after_ms }) => {
                            assert!(
                                (1..=30_000).contains(&retry_after_ms),
                                "retry hint must be finite and bounded"
                            );
                            tally[1] += 1;
                        }
                        Err(SubmitError::DeadlineExceeded) => tally[2] += 1,
                        Err(SubmitError::Full) => tally[3] += 1,
                        Err(SubmitError::Closed) => tally[4] += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                tally
            }));
        }
        drop(tx);
        let mut tally = [0u64; 5];
        for h in handles {
            for (a, b) in tally.iter_mut().zip(h.join().unwrap()) {
                *a += b;
            }
        }
        let [accepted, overloaded, dl_admission, full, closed] = tally;
        assert_eq!(
            accepted + overloaded + dl_admission + full + closed,
            THREADS * PER,
            "every submit must land in exactly one bucket"
        );
        assert_eq!(closed, 0, "nothing closed the queue mid-run");

        // every accepted request gets exactly one answer
        let mut answered = 0u64;
        let mut dl_batch = 0u64;
        for _ in 0..accepted {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("answer lost");
            match resp.result {
                Ok(_) => answered += 1,
                Err(ServeError::DeadlineExceeded { .. }) => dl_batch += 1,
                Err(e) => panic!("unexpected serve error: {e}"),
            }
        }
        assert!(rx.try_recv().is_err(), "more answers than accepted requests");

        // the observable counters agree with ground truth exactly
        let (c_overloaded, c_dl_admission, c_dl_batch) = engine.overload_counts();
        assert_eq!(c_overloaded, overloaded);
        assert_eq!(c_dl_admission, dl_admission);
        assert_eq!(c_dl_batch, dl_batch);
        let (c_full, c_closed) = engine.shed_counts();
        assert_eq!((c_full, c_closed), (full, 0));
        // the conservation identity, in counter terms:
        // answered + shed + overloaded + deadline_expired == submitted
        assert_eq!(
            answered + c_full + c_closed + c_overloaded + c_dl_admission + c_dl_batch,
            THREADS * PER,
        );
        engine.shutdown();
    }

    /// An injected panic inside `Backend::infer` must degrade to
    /// per-request `inference_failed` answers — the worker survives,
    /// and after `clear()` the same engine serves normally.
    #[test]
    fn worker_panics_degrade_to_answers_and_the_worker_recovers() {
        let _armed = armed();
        failpoint::configure("worker_infer", Action::Panic(1.0));
        let reg = Registry::new();
        let engine = chaos_engine(
            EngineConfig {
                workers: 1,
                queue_capacity: 16,
                max_delay: Duration::from_millis(1),
                ..EngineConfig::default()
            },
            &reg,
        );
        let numel = engine.input_numel();
        let (tx, rx) = mpsc::channel();
        for id in 0..8u64 {
            engine.submit(id, vec![0.0; numel], tx.clone()).unwrap();
        }
        for _ in 0..8 {
            let resp = rx.recv_timeout(Duration::from_secs(10)).expect("answer lost");
            match resp.result {
                Err(ServeError::Inference(msg)) => {
                    assert!(msg.contains("panicked"), "unexpected message {msg:?}")
                }
                other => panic!("expected an inference error, got {other:?}"),
            }
        }
        assert_eq!(engine.metrics.failures.load(Ordering::SeqCst), 8);

        // the panic never took the worker down: disarm and serve
        failpoint::clear();
        let resp = engine.infer_blocking(vec![0.0; numel]).unwrap();
        assert!(resp.result.is_ok(), "worker did not recover: {:?}", resp.result);
        engine.shutdown();
    }

    /// Injected connection resets on the server's write path close that
    /// connection only — the listener and engine keep serving, and a
    /// fresh connection round-trips after `clear()`.
    #[test]
    fn connection_write_resets_leave_the_server_serving() {
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpStream;

        let _armed = armed();
        let reg = Registry::new();
        let engine = chaos_engine(
            EngineConfig {
                workers: 1,
                queue_capacity: 16,
                max_delay: Duration::from_millis(1),
                ..EngineConfig::default()
            },
            &reg,
        );
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();
        failpoint::configure("conn_write", Action::Reset(1.0));

        let mut stream = TcpStream::connect(server.addr).unwrap();
        writeln!(stream, r#"{{"id":1,"image":[0,0,0,0]}}"#).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        // the reply write hits the reset: the server drops this
        // connection instead of answering
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "got {line:?}");

        failpoint::clear();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        writeln!(stream, r#"{{"id":2,"image":[0,0,0,0]}}"#).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").and_then(Json::as_f64), Some(2.0));
        assert!(j.get("class").is_some(), "server did not recover: {line}");

        server.stop();
        engine.shutdown();
    }

    /// Shutdown while the batcher is stalling: every accepted request
    /// is still answered before `shutdown()` returns — drain means
    /// finish, not abandon.
    #[test]
    fn drain_answers_every_accepted_request_despite_stalls() {
        let _armed = armed();
        failpoint::configure("batcher_stall", Action::Sleep(20));
        let reg = Registry::new();
        let engine = chaos_engine(
            EngineConfig {
                workers: 1,
                queue_capacity: 64,
                max_delay: Duration::from_millis(1),
                ..EngineConfig::default()
            },
            &reg,
        );
        let numel = engine.input_numel();
        let (tx, rx) = mpsc::channel();
        for id in 0..32u64 {
            engine.submit(id, vec![0.0; numel], tx.clone()).unwrap();
        }
        engine.shutdown(); // close + drain + join
        drop(tx);
        let mut answered = 0u64;
        while let Ok(resp) = rx.try_recv() {
            assert!(resp.result.is_ok(), "drained request failed: {:?}", resp.result);
            answered += 1;
        }
        assert_eq!(answered, 32, "drain abandoned accepted requests");
    }
}

//! Offline end-to-end tests for the native *resnet* training backend —
//! no AOT artifacts, no PJRT, no Python (DESIGN.md §18). The residual
//! sibling of `tests/conv_native.rs`: a real gradient-descent run on a
//! resnet20-class topology (stem → residual blocks with identity and
//! 1×1-projection shortcuts → GAP → fc) feeds the AdaQAT controller
//! *measured* probe losses, the run exports an `AQQCKPT1` checkpoint
//! whose meta carries `res_blocks`, and the integer residual kernels
//! serve it with every prediction matching the trainer's eval forward.

use std::path::{Path, PathBuf};

use adaqat::backprop::{ResNetNativeBackend, NATIVE_RESNET_KEY};
use adaqat::config::{ControllerKind, ExperimentConfig};
use adaqat::coordinator::{self, Experiment};
use adaqat::data::{synth, DatasetKind};
use adaqat::runtime::StepBackend;
use adaqat::serve::{QuantizedCheckpoint, ReferenceBackend};
use adaqat::tensor::checkpoint::Checkpoint;

/// Small offline config: 8×8 synthetic images, two stages of one
/// residual block each ([4, 8] channels → one identity block, one
/// stride-2 projection block), GAP over 4×4×8, 16-sample batches —
/// sized so the suite stays fast in debug builds while the loss
/// surface still shows the low-bit wall the controller feeds on.
fn res_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(NATIVE_RESNET_KEY);
    cfg.model = NATIVE_RESNET_KEY.to_string();
    cfg.backend = "native".to_string();
    cfg.dataset = "cifar10".to_string();
    cfg.image_hw = 8;
    cfg.batch = 16;
    cfg.channels = vec![4, 8];
    cfg.blocks = 1;
    cfg.train_size = 256;
    cfg.test_size = 64;
    cfg.lr = 0.05;
    cfg.epochs = 3;
    cfg
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("adaqat_res_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Export a finished run and cross-check every served prediction
/// against the trainer's serving-identical eval forward.
fn export_and_check(
    backend: &ResNetNativeBackend,
    out_dir: &Path,
    k_w: u32,
    k_a: u32,
    expect_quantized: usize,
) {
    let ck = Checkpoint::load(&out_dir.join("final.ckpt")).unwrap();
    assert!(ck.meta.get("res_blocks").is_some(), "residual serving meta missing");
    assert!(ck.meta.get("mlp_layers").is_some(), "fc-head serving meta missing");
    let (q, report) = coordinator::export_packed(&ck, k_w).unwrap();
    assert_eq!(report.k_w, k_w);
    assert_eq!(
        report.quantized_tensors, expect_quantized,
        "the six unit `.w` tensors and fc1.w must pack; BN tensors stay raw"
    );
    let aqq = out_dir.join("final.aqq");
    q.save(&aqq).unwrap();

    let served =
        ReferenceBackend::from_packed(&QuantizedCheckpoint::load(&aqq).unwrap()).unwrap();
    let state = backend.load_state(&ck, 0).unwrap();
    let ds = synth::generate_sized(DatasetKind::Cifar10, 64, 99, 1, 8, 8);
    for i in 0..64 {
        let want = backend.predict(&state, ds.image(i), 1, k_w, k_a).unwrap()[0];
        assert_eq!(
            served.classify_one(ds.image(i)),
            want,
            "sample {i}: served prediction diverged from the trainer's eval forward"
        );
    }
}

/// The acceptance path: a full AdaQAT run on measured residual-net
/// losses → freeze via oscillation → export → serve through the
/// integer residual kernels → bit-identical predictions.
#[test]
fn full_adaqat_resnet_run_exports_and_serves_identically() {
    let mut cfg = res_cfg();
    cfg.epochs = 12; // 192 steps: descent + oscillation + margin
    cfg.controller = ControllerKind::AdaQat;
    // Same tuning rationale as the smallcnn e2e: batch-norm after every
    // conv renormalizes post-quantization, so ΔL(1→2 bits) is ~1 nat
    // and λ = 0.1 keeps the hardware pull under that wall while still
    // dominating the flat high-bit region — N_w settles into the
    // oscillation band instead of ramming the 1-bit clamp. Residual
    // joins only add f32 sums on top of the same BN'd conv units, so
    // the surface shape carries over.
    cfg.init_nw = 5.0;
    cfg.init_na = 8.0;
    cfg.eta_w = 0.05;
    cfg.eta_a = 0.0;
    cfg.lambda = 0.1;
    cfg.osc_threshold = 2;
    cfg.probe_interval = 1;
    let out_dir = tmpdir("e2e");
    cfg.out_dir = Some(out_dir.clone());

    let backend = ResNetNativeBackend::from_config(&cfg).unwrap();
    let exp = Experiment::new(&backend, cfg).unwrap();
    let result = exp.run().unwrap();

    // the controller ran on measured residual-net losses and froze the
    // weight axis (freeze picks the larger point, so k_w >= 2)
    assert!(!result.trace.is_empty(), "controller never probed");
    assert!(result.trace.iter().all(|t| t.train_loss.is_finite()));
    let (k_w, k_a) = result.final_bits;
    assert_eq!(k_a, 8, "eta_a = 0 must pin activations");
    assert!(
        (2..=8).contains(&k_w),
        "frozen k_w = {k_w} outside the expected band (N trace: {:?})",
        result.trace.iter().map(|t| t.n_w).collect::<Vec<_>>()
    );
    assert!(
        result.trace.iter().any(|t| t.osc_w >= 2),
        "weight axis should have frozen via oscillation, max osc = {:?}",
        result.trace.iter().map(|t| t.osc_w).max()
    );
    // loss moved: a real training signal, not the synthetic landscape
    let first = result.epochs.first().unwrap().train_loss;
    let last = result.epochs.last().unwrap().train_loss;
    assert!(last < first, "train loss did not improve: {first} -> {last}");

    export_and_check(&backend, &out_dir, k_w, k_a, 7);
    std::fs::remove_dir_all(&out_dir).ok();
}

/// The robustness core, independent of controller dynamics: a fixed
/// 4/8 run round-trips through export → serve with bit-identical
/// predictions across both shortcut kinds.
#[test]
fn fixed_controller_resnet_run_round_trips_through_serving() {
    let mut cfg = res_cfg();
    cfg.controller = ControllerKind::Fixed { k_w: 4, k_a: 8 };
    let out_dir = tmpdir("fixed");
    cfg.out_dir = Some(out_dir.clone());
    let backend = ResNetNativeBackend::from_config(&cfg).unwrap();
    let result = Experiment::new(&backend, cfg).unwrap().run().unwrap();
    assert_eq!(result.final_bits, (4, 8));
    assert!(result.test_top1 > 0.0);
    export_and_check(&backend, &out_dir, 4, 8, 7);
    std::fs::remove_dir_all(&out_dir).ok();
}

/// Same seed ⇒ bit-identical run (the residual backend is
/// single-threaded math over a deterministic pipeline).
#[test]
fn same_seed_gives_identical_resnet_run() {
    let mut cfg = res_cfg();
    cfg.epochs = 2;
    cfg.controller = ControllerKind::AdaQat;
    cfg.seed = 11;
    let run = |cfg: &ExperimentConfig| {
        let backend = ResNetNativeBackend::from_config(cfg).unwrap();
        Experiment::new(&backend, cfg.clone()).unwrap().run().unwrap()
    };
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.final_bits, b.final_bits);
    assert_eq!(a.trace.len(), b.trace.len());
    for (x, y) in a.trace.iter().zip(&b.trace) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.n_w.to_bits(), y.n_w.to_bits());
    }
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
    }
    cfg.seed = 12;
    let c = run(&cfg);
    assert!(
        a.epochs[0].train_loss.to_bits() != c.epochs[0].train_loss.to_bits(),
        "seed change should change the trajectory"
    );
}

/// The measured residual probe-loss surface: after some training,
/// fewer weight bits ⇒ higher task loss — the wall the oscillation
/// freeze relies on, now with skip connections in the way.
#[test]
fn measured_resnet_loss_surface_has_a_low_bit_wall() {
    let cfg = res_cfg();
    let backend = ResNetNativeBackend::from_config(&cfg).unwrap();
    let exp = Experiment::new(&backend, cfg.clone()).unwrap();
    let mut state = backend.init_state(3).unwrap();
    let batches = exp.train_loader.epoch(1);
    for _ in 0..3 {
        for batch in &batches {
            backend.train_step(&mut state, batch, 0.05, 8, 8, false).unwrap();
        }
    }
    let probe = |k_w: u32| {
        backend.probe_loss(&state, &batches[0], k_w, 8).unwrap().loss
    };
    let (l1, l8) = (probe(1), probe(8));
    assert!(l1.is_finite() && l8.is_finite());
    assert!(
        l1 > l8 + 0.05,
        "1-bit resnet weights should hurt a trained net: L(1)={l1} vs L(8)={l8}"
    );
}

//! End-to-end serving test (DESIGN.md §7) — runs fully offline, no AOT
//! artifacts or PJRT needed: demo checkpoint → `export` packing →
//! engine + dynamic batcher → TCP server → pipelined client, 1k+
//! requests, every prediction cross-checked against the model's direct
//! (unbatched) forward pass.

use std::sync::Arc;
use std::time::Duration;

use adaqat::coordinator::export_packed;
use adaqat::data::{synth, DatasetKind};
use adaqat::serve::client;
use adaqat::serve::demo;
use adaqat::serve::{
    Backend, Engine, EngineConfig, QuantizedCheckpoint, ReferenceBackend, Server,
};

#[test]
fn serve_end_to_end_1k_requests_over_tcp() {
    let tmp = std::env::temp_dir().join(format!("adaqat_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();

    // 1. train-time artifact: the demo checkpoint (fp32)
    let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 32, 7, 16);
    let ck_path = tmp.join("model.ckpt");
    ck.save(&ck_path).unwrap();

    // 2. export to the packed serving format at 4 bits, through disk
    let (q, report) = export_packed(&ck, 4).unwrap();
    assert_eq!(report.quantized_tensors, 1);
    let packed_path = tmp.join("model.aqq");
    q.save(&packed_path).unwrap();
    // packed ≤ 1/6 of the fp32 source on disk (acceptance criterion)
    let fp32_bytes = std::fs::metadata(&ck_path).unwrap().len();
    let packed_bytes = std::fs::metadata(&packed_path).unwrap().len();
    assert!(
        packed_bytes * 6 <= fp32_bytes,
        "packed {packed_bytes} vs fp32 {fp32_bytes}"
    );
    let packed = Arc::new(QuantizedCheckpoint::load(&packed_path).unwrap());

    // 3. engine with 2 workers + dynamic batching
    let packed2 = Arc::clone(&packed);
    let engine = Engine::start(
        EngineConfig {
            workers: 2,
            queue_capacity: 2048,
            max_delay: Duration::from_millis(2),
            ..EngineConfig::default()
        },
        move |_| Ok(Box::new(ReferenceBackend::from_packed(&packed2)?) as Box<dyn Backend>),
    )
    .unwrap();

    // 4. TCP server + pipelined demo client, 1024 single-image requests
    let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();
    let n = 1024usize;
    let ds = synth::generate(DatasetKind::Cifar10, n, 99, 1);
    let images: Vec<(Vec<f32>, i32)> =
        (0..n).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
    let report = client::run(&server.addr.to_string(), &images, 64).unwrap();

    // every request answered, none dropped or failed
    assert_eq!(report.sent, n);
    assert_eq!(report.received, n);
    assert_eq!(report.errors, 0);
    assert_eq!(report.preds.len(), n);

    // 5. correctness: the pipelined path agrees with the direct forward
    //    for all 1k requests…
    let direct = ReferenceBackend::from_packed(&packed).unwrap();
    for (id, outcome) in &report.preds {
        let want = direct.classify_one(ds.image(*id as usize));
        assert_eq!(outcome.as_ref().ok().copied(), Some(want), "request {id}");
    }
    // …and the demo model genuinely classifies (≫ 10-class chance)
    let acc = report.correct as f64 / n as f64;
    assert!(acc > 0.2, "served accuracy only {acc:.3}");

    // 6. latency accounting covered every request
    assert_eq!(engine.metrics.requests.load(std::sync::atomic::Ordering::Relaxed), n as u64);
    assert_eq!(engine.metrics.queue.count(), n as u64);
    assert_eq!(engine.metrics.compute.count(), n as u64);
    let snap = engine.metrics.queue.snapshot();
    assert!(snap.p50_ms <= snap.p95_ms && snap.p95_ms <= snap.p99_ms);
    // dynamic batching actually coalesced: far fewer batches than requests
    let batches = engine.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches < n as u64, "no coalescing happened ({batches} batches)");

    server.stop();
    engine.shutdown();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn serve_mlp_end_to_end_through_integer_kernels() {
    // The kernels-backed path: 2-layer ReLU demo MLP, 4-bit packed
    // weights, 8-bit on-the-fly activations, i8 codes + i32
    // accumulation, 2 GEMM threads per worker — full TCP stack, every
    // prediction cross-checked against the direct (batch-1) forward.
    // Per-row activation scales make that comparison exact: a request's
    // codes never depend on its batch neighbours.
    let ck = demo::demo_mlp_checkpoint(DatasetKind::Cifar10, 128, 8, 11, 16, 8);
    let (q, report) = export_packed(&ck, 4).unwrap();
    assert_eq!(report.quantized_tensors, 2, "fc1.w and fc2.w");
    let q = Arc::new(q);
    let q2 = Arc::clone(&q);
    let engine = Engine::start(
        EngineConfig {
            workers: 2,
            queue_capacity: 1024,
            max_delay: Duration::from_millis(2),
            ..EngineConfig::default()
        },
        move |_| {
            Ok(Box::new(ReferenceBackend::with_threads(&q2, 2)?) as Box<dyn Backend>)
        },
    )
    .unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();
    let n = 512usize;
    let ds = synth::generate(DatasetKind::Cifar10, n, 101, 1);
    let images: Vec<(Vec<f32>, i32)> =
        (0..n).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
    let report = client::run(&server.addr.to_string(), &images, 32).unwrap();
    assert_eq!(report.received, n);
    assert_eq!(report.errors, 0);

    let direct = ReferenceBackend::from_packed(&q).unwrap();
    for (id, outcome) in &report.preds {
        let want = direct.classify_one(ds.image(*id as usize));
        assert_eq!(outcome.as_ref().ok().copied(), Some(want), "request {id}");
    }
    // centroid pairs reconstruct the linear demo's scores through the
    // ReLU, so 4-bit MLP accuracy stays far above 10-class chance
    let acc = report.correct as f64 / n as f64;
    assert!(acc > 0.25, "served MLP accuracy only {acc:.3}");

    server.stop();
    engine.shutdown();
}

/// Every exposition line must parse as `name{labels} value` (label
/// values in this codebase never contain spaces, so the value is the
/// last space-separated token). Returns the metric name.
fn parse_prom_line(line: &str) -> String {
    let (lhs, val) = line
        .rsplit_once(' ')
        .unwrap_or_else(|| panic!("no value separator in {line:?}"));
    val.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
    let name = match lhs.split_once('{') {
        Some((name, rest)) => {
            assert!(rest.ends_with('}'), "unterminated label block in {line:?}");
            name
        }
        None => lhs,
    };
    let well_formed = name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_');
    assert!(!name.is_empty() && well_formed, "bad metric name in {line:?}");
    name.to_string()
}

#[test]
fn serve_metrics_exposition_and_trace_over_tcp() {
    // DESIGN.md §15: after one classified request, the `metrics` command
    // must return a parseable Prometheus exposition carrying the
    // per-layer kernel series, and the `trace` command must return that
    // request's span with monotone pipeline timestamps. The trace is
    // pushed before the reply is sent, so reading our own answer first
    // makes both checks deterministic.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    use adaqat::util::json::Json;

    let ck = demo::demo_mlp_checkpoint(DatasetKind::Cifar10, 64, 4, 21, 8, 8);
    let (q, _) = export_packed(&ck, 4).unwrap();
    let q = Arc::new(q);
    let q2 = Arc::clone(&q);
    let engine = Engine::start(
        EngineConfig {
            workers: 1,
            queue_capacity: 64,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        },
        move |_| Ok(Box::new(ReferenceBackend::with_threads(&q2, 2)?) as Box<dyn Backend>),
    )
    .unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();

    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    // 1. one inference so the layer histograms and trace ring have data
    let ds = synth::generate(DatasetKind::Cifar10, 4, 13, 1);
    let image = Json::Arr(ds.image(0).iter().map(|&v| Json::num(v as f64)).collect());
    let req = Json::obj(vec![("id", Json::num(42.0)), ("image", image)]).to_string();
    writeln!(stream, "{req}").unwrap();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_f64), Some(42.0));
    assert!(resp.get("class").is_some(), "infer failed: {line}");

    // 2. metrics: single NDJSON frame wrapping the multi-line exposition
    writeln!(stream, r#"{{"cmd": "metrics"}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.matches('\n').count(), 1, "frame must be one line");
    let j = Json::parse(&line).unwrap();
    let text = j.get("metrics").unwrap().as_str().unwrap().to_string();
    let names: Vec<String> = text.lines().map(parse_prom_line).collect();
    assert!(!names.is_empty());
    // per-layer kernel telemetry with the full label set
    let layer_series = text.lines().any(|l| {
        l.starts_with("adaqat_layer_forward_ms") && l.contains("plan=\"") && l.contains("k_w=\"")
    });
    assert!(layer_series, "no labeled per-layer series in:\n{text}");
    // queue + pool gauges (live regardless of the sampler switch)
    assert!(names.iter().any(|n| n == "adaqat_queue_depth"), "{text}");
    assert!(names.iter().any(|n| n == "adaqat_pool_active"), "{text}");
    // engine mirror counters accounted for our request
    let mirror = "adaqat_requests_total";
    let counted = text.lines().any(|l| l.starts_with(mirror) && !l.ends_with(" 0"));
    assert!(counted, "requests_total still zero in:\n{text}");

    // 3. trace: our span is present with monotone timestamps
    writeln!(stream, r#"{{"cmd": "trace"}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    let traces = j.get("traces").unwrap().as_arr().unwrap().to_vec();
    let span = traces
        .iter()
        .find(|t| t.get("id").and_then(Json::as_f64) == Some(42.0))
        .unwrap_or_else(|| panic!("request 42 not traced: {line}"));
    let us = |k: &str| span.get(k).and_then(Json::as_f64).unwrap();
    let (enq, bat, comp, rep) =
        (us("enqueue_us"), us("batch_us"), us("compute_done_us"), us("reply_us"));
    assert!(
        enq <= bat && bat <= comp && comp <= rep,
        "span not monotone: {enq} {bat} {comp} {rep}"
    );
    assert!(us("rows") >= 1.0, "span must cover at least its own row");
    assert_eq!(span.get("ok").and_then(Json::as_bool), Some(true));

    server.stop();
    engine.shutdown();
}

#[test]
fn serve_sheds_load_instead_of_buffering_unboundedly() {
    // tiny queue + one slow-ish worker: the client must see explicit
    // backpressure errors, not hangs
    let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 4, 3, 4);
    let (q, _) = export_packed(&ck, 4).unwrap();
    let q = Arc::new(q);
    let q2 = Arc::clone(&q);
    let engine = Engine::start(
        EngineConfig {
            workers: 1,
            queue_capacity: 2,
            max_delay: Duration::from_millis(50),
            ..EngineConfig::default()
        },
        move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
    )
    .unwrap();
    let numel = engine.input_numel();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut accepted = 0usize;
    let mut shed = 0usize;
    for i in 0..64u64 {
        match engine.submit(i, vec![0.0; numel], tx.clone()) {
            Ok(()) => accepted += 1,
            Err(adaqat::serve::engine::SubmitError::Full) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed > 0, "a 2-deep queue cannot absorb 64 instant submits");
    for _ in 0..accepted {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
    }
    engine.shutdown();
}

#[test]
fn serve_deadline_expiry_is_a_structured_wire_error() {
    // DESIGN.md §19: an unmeetable deadline is answered, never computed.
    // `deadline_ms: 0` expires at admission deterministically; the reply
    // must carry the machine code + stage, and a roomy deadline on the
    // same connection must still classify.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    use adaqat::util::json::Json;

    let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 4, 17, 8);
    let (q, _) = export_packed(&ck, 4).unwrap();
    let q = Arc::new(q);
    let q2 = Arc::clone(&q);
    let engine = Engine::start(
        EngineConfig {
            workers: 1,
            queue_capacity: 64,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        },
        move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
    )
    .unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();

    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    let ds = synth::generate(DatasetKind::Cifar10, 2, 23, 1);
    let image = |i: usize| {
        Json::Arr(ds.image(i).iter().map(|&v| Json::num(v as f64)).collect()).to_string()
    };

    writeln!(stream, r#"{{"id":1,"image":{},"deadline_ms":0}}"#, image(0)).unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("id").and_then(Json::as_f64), Some(1.0));
    assert_eq!(j.get("error").and_then(Json::as_str), Some("deadline_exceeded"));
    assert_eq!(j.get("stage").and_then(Json::as_str), Some("admission"));
    assert!(j.get("class").is_none(), "expired request must not be answered");

    line.clear();
    writeln!(stream, r#"{{"id":2,"image":{},"deadline_ms":60000}}"#, image(1)).unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("id").and_then(Json::as_f64), Some(2.0));
    assert!(j.get("class").is_some(), "roomy deadline must classify: {line}");

    // the expiry landed on the admission counter, not the batch one
    let (rejected, dl_admission, dl_batch) = engine.overload_counts();
    assert_eq!(rejected, 0);
    assert_eq!(dl_admission, 1);
    assert_eq!(dl_batch, 0);

    server.stop();
    engine.shutdown();
}

/// Fixed-delay backend: makes overload deterministic without tuning
/// real kernels (4-wide batches, `delay` per forward).
struct SlowBackend {
    delay: Duration,
}

impl Backend for SlowBackend {
    fn input_shape(&self) -> (usize, usize, usize) {
        (2, 2, 1)
    }
    fn max_batch(&self) -> usize {
        4
    }
    fn num_classes(&self) -> usize {
        10
    }
    fn infer(&self, x: &adaqat::tensor::Tensor) -> anyhow::Result<Vec<usize>> {
        std::thread::sleep(self.delay);
        Ok(vec![0; x.shape[0]])
    }
}

#[test]
fn serve_overload_retry_after_round_trip_resolves_all_requests() {
    // ~an order of magnitude more offered load than a 4-deep queue over
    // a slow worker can hold: admission control must reject with finite
    // retry_after_ms hints and the client's jittered backoff must land
    // every request eventually — no hangs, no lost answers, no
    // budget-exhausted sheds.
    let engine = Engine::start(
        EngineConfig {
            workers: 1,
            queue_capacity: 4,
            max_delay: Duration::from_millis(1),
            max_wait: Some(Duration::from_millis(50)),
            ..EngineConfig::default()
        },
        move |_| {
            Ok(Box::new(SlowBackend { delay: Duration::from_millis(20) })
                as Box<dyn Backend>)
        },
    )
    .unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();

    let n = 128usize;
    let images: Vec<(Vec<f32>, i32)> = (0..n).map(|_| (vec![0.5; 4], 0)).collect();
    let cfg = client::ClientConfig {
        window: 32,
        max_retries: 12,
        deadline_ms: None,
        seed: 7,
    };
    let report = client::run_with(&server.addr.to_string(), &images, &cfg).unwrap();

    assert_eq!(report.received, n);
    assert_eq!(report.errors, 0, "retries must resolve every request");
    assert_eq!(report.shed, 0);
    assert!(report.retried > 0, "this load must trip admission control");
    assert_eq!(report.attempted, n + report.retried);

    // the server really rejected (the client's retries are not an
    // artifact), and rejection implies a served retry hint
    let (rejected, dl_admission, dl_batch) = engine.overload_counts();
    assert!(rejected > 0, "admission control never fired");
    assert_eq!(dl_admission + dl_batch, 0, "no deadlines were set");

    server.stop();
    engine.shutdown();
}

/// Kill the child on panic so a failed assert can't leak a server.
struct KillOnDrop(std::process::Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn serve_binary_drains_gracefully_and_exits_zero() {
    // The real `adaqat serve` process: answer traffic, take a
    // {"cmd":"drain"}, finish up, flush --metrics_out, exit 0.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::process::{Command, Stdio};

    use adaqat::util::json::Json;

    let tmp = std::env::temp_dir().join(format!("adaqat_drain_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 4, 29, 8);
    let (q, _) = export_packed(&ck, 4).unwrap();
    let packed_path = tmp.join("model.aqq");
    q.save(&packed_path).unwrap();
    let metrics_path = tmp.join("metrics.prom");

    let child = Command::new(env!("CARGO_BIN_EXE_adaqat"))
        .args([
            "serve",
            "--checkpoint",
            packed_path.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--workers",
            "1",
            "--metrics_out",
            metrics_path.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .stdin(Stdio::null())
        .spawn()
        .unwrap();
    let mut child = KillOnDrop(child);
    let mut child_out = BufReader::new(child.0.stdout.take().unwrap());

    // the banner line carries the bound address: "serving X on ADDR (…)"
    let mut banner = String::new();
    child_out.read_line(&mut banner).unwrap();
    let addr = banner
        .split(" on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in banner {banner:?}"));

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    let ds = synth::generate(DatasetKind::Cifar10, 1, 31, 1);
    let image =
        Json::Arr(ds.image(0).iter().map(|&v| Json::num(v as f64)).collect()).to_string();
    writeln!(stream, r#"{{"id":7,"image":{image}}}"#).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(
        Json::parse(&line).unwrap().get("class").is_some(),
        "infer before drain failed: {line}"
    );

    line.clear();
    writeln!(stream, r#"{{"cmd":"drain"}}"#).unwrap();
    reader.read_line(&mut line).unwrap();
    let ack = Json::parse(&line).unwrap();
    assert_eq!(ack.get("draining").and_then(Json::as_bool), Some(true), "{line}");

    // the serve loop polls its drain flag every 200ms; allow generous
    // slack for the final metrics flush before calling it a hang
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let status = loop {
        if let Some(status) = child.0.try_wait().unwrap() {
            break status;
        }
        assert!(std::time::Instant::now() < deadline, "drain did not exit");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "drain must exit 0, got {status:?}");
    let exposition = std::fs::read_to_string(&metrics_path).unwrap();
    assert!(!exposition.is_empty(), "drain must flush --metrics_out");
    for l in exposition.lines() {
        parse_prom_line(l);
    }
    std::fs::remove_dir_all(&tmp).ok();
}

//! Integration tests over the real AOT artifacts (`make artifacts` first).
//!
//! These exercise the full L3→L2→L1 composition: Rust initializes state,
//! uploads batches, executes the compiled HLO (which contains the Pallas
//! quantizer kernels), and steers bit-widths — on the smallcnn artifacts
//! to stay fast.

use std::path::Path;

use adaqat::adaqat::{AdaQatController, FixedController};
use adaqat::config::{ControllerKind, ExperimentConfig, Scenario};
use adaqat::coordinator::{ensure_fp32_pretrain, Experiment};
use adaqat::data::{loader::Loader, synth, DatasetKind};
use adaqat::runtime::{bitwidth_scale, Batch, Runtime, S_IDENTITY};
use adaqat::tensor::checkpoint::Checkpoint;
use adaqat::train;

// PjRtClient is Rc-based (!Send), so each test owns its runtime.
fn try_runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return None;
    }
    Some(Runtime::new(&dir).expect("artifacts present but runtime failed to open them"))
}

/// Evaluates to a [`Runtime`], or returns from the test (as a skip) when
/// the AOT artifacts have not been built in this checkout.
macro_rules! require_artifacts {
    () => {
        match try_runtime() {
            Some(rt) => rt,
            None => {
                eprintln!("skipping: no AOT artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

fn small_batch(rt: &adaqat::runtime::ModelRuntime, seed: u64) -> Batch {
    let ds = synth::generate(DatasetKind::Cifar10, rt.mm.batch, seed, 0).into_shared();
    Loader::new(ds, rt.mm.batch, false).epoch(0).remove(0)
}

#[test]
fn manifest_covers_all_models() {
    let rt = require_artifacts!();
    for key in ["smallcnn", "resnet20", "resnet18", "smallcnn_pallas"] {
        let mm = rt.manifest.model(key).unwrap();
        assert!(mm.param_count() > 0);
        assert!(!mm.geoms.is_empty());
    }
    // paper-scale sanity: resnet20 ≈ 0.27M weights, resnet18 ≈ 11M
    let r20 = rt.manifest.model("resnet20").unwrap();
    assert!((250_000..320_000).contains(&r20.weight_count()));
    let r18 = rt.manifest.model("resnet18").unwrap();
    assert!((10_000_000..12_500_000).contains(&r18.weight_count()));
}

#[test]
fn train_step_decreases_loss_and_updates_state() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let mut state = rt.init_state(0).unwrap();
    let p0 = state.params[0].clone();
    let batch = small_batch(&rt, 42);
    let s = bitwidth_scale(4);
    let first = rt.train_step(&mut state, &batch, 0.1, s, s, false).unwrap();
    assert!(first.loss.is_finite());
    assert_ne!(state.params[0], p0, "params must move");
    let mut last = first;
    for _ in 0..20 {
        last = rt.train_step(&mut state, &batch, 0.1, s, s, false).unwrap();
    }
    assert!(
        last.loss < first.loss * 0.7,
        "loss did not decrease: {} -> {}",
        first.loss,
        last.loss
    );
    assert!(state.is_finite());
    assert!(last.correct >= first.correct);
}

#[test]
fn fp32_graph_trains_too() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let mut state = rt.init_state(1).unwrap();
    let batch = small_batch(&rt, 7);
    let first = rt.train_step(&mut state, &batch, 0.1, 0.0, 0.0, true).unwrap();
    let mut last = first;
    for _ in 0..15 {
        last = rt.train_step(&mut state, &batch, 0.1, 0.0, 0.0, true).unwrap();
    }
    assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
}

#[test]
fn probe_loss_is_deterministic_and_bit_sensitive() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let mut state = rt.init_state(2).unwrap();
    let batch = small_batch(&rt, 3);
    // train a bit at 8/8 so low bit-widths actually hurt
    let s8 = bitwidth_scale(8);
    for _ in 0..25 {
        rt.train_step(&mut state, &batch, 0.1, s8, s8, false).unwrap();
    }
    let a = rt.probe_loss(&state, &batch, s8, s8).unwrap();
    let b = rt.probe_loss(&state, &batch, s8, s8).unwrap();
    assert_eq!(a.loss, b.loss, "probe must be deterministic");
    let low = rt.probe_loss(&state, &batch, bitwidth_scale(1), s8).unwrap();
    assert!(
        low.loss > a.loss,
        "1-bit weights should hurt: {} vs {}",
        low.loss,
        a.loss
    );
}

#[test]
fn identity_scale_matches_high_bits() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let state = rt.init_state(3).unwrap();
    let batch = small_batch(&rt, 5);
    let id = rt.probe_loss(&state, &batch, S_IDENTITY, S_IDENTITY).unwrap();
    let hi = rt
        .probe_loss(&state, &batch, bitwidth_scale(16), bitwidth_scale(16))
        .unwrap();
    assert!((id.loss - hi.loss).abs() < 1e-3, "{} vs {}", id.loss, hi.loss);
}

#[test]
fn eval_uses_running_stats() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let mut state = rt.init_state(4).unwrap();
    let batch = small_batch(&rt, 11);
    let s = bitwidth_scale(8);
    // Fresh BN running stats (mean 0, var 1) are wrong for real data, so
    // eval loss differs from the batch-stat probe loss; after training
    // the two converge. Here just check eval runs and is deterministic.
    let e1 = rt.eval_batch(&state, &batch, s, s, false).unwrap();
    let e2 = rt.eval_batch(&state, &batch, s, s, false).unwrap();
    assert_eq!(e1.loss, e2.loss);
    for _ in 0..10 {
        rt.train_step(&mut state, &batch, 0.1, s, s, false).unwrap();
    }
    let e3 = rt.eval_batch(&state, &batch, s, s, false).unwrap();
    assert!(e3.loss < e1.loss);
}

#[test]
fn pallas_conv_variant_composes_end_to_end() {
    // The all-Pallas path: convs lowered through the L1 tiled matmul.
    let rt = require_artifacts!().load_model("smallcnn_pallas").unwrap();
    let mut state = rt.init_state(5).unwrap();
    let batch = small_batch(&rt, 13);
    let s = bitwidth_scale(4);
    let first = rt.train_step(&mut state, &batch, 0.1, s, s, false).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = rt.train_step(&mut state, &batch, 0.1, s, s, false).unwrap();
    }
    assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
}

#[test]
fn pallas_and_lax_conv_agree_numerically() {
    // Same init, same batch, same scales → the two conv lowerings must
    // produce near-identical losses (they compute the same function).
    let rt_a = require_artifacts!().load_model("smallcnn").unwrap();
    let rt_b = require_artifacts!().load_model("smallcnn_pallas").unwrap();
    let state_a = rt_a.init_state(6).unwrap();
    let state_b = rt_b.init_state(6).unwrap(); // same seed → same init
    let batch = small_batch(&rt_a, 17);
    let s = bitwidth_scale(6);
    let la = rt_a.probe_loss(&state_a, &batch, s, s).unwrap();
    let lb = rt_b.probe_loss(&state_b, &batch, s, s).unwrap();
    assert!(
        (la.loss - lb.loss).abs() < 1e-3,
        "lax {} vs pallas {}",
        la.loss,
        lb.loss
    );
}

#[test]
fn full_experiment_with_adaqat_controller() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let mut cfg = ExperimentConfig::default_for("smallcnn");
    cfg.epochs = 2;
    cfg.train_size = 512;
    cfg.test_size = 128;
    cfg.lambda = 0.15;
    // big etas so bit-widths actually move in a 2-epoch smoke run
    cfg.eta_w = 0.05;
    cfg.eta_a = 0.02;
    let exp = Experiment::new(&rt, cfg).unwrap();
    let result = exp.run().unwrap();
    assert_eq!(result.epochs.len(), 2);
    assert!(result.test_top1 > 0.15, "top1 {}", result.test_top1);
    assert!(!result.trace.is_empty(), "controller must have probed");
    let (kw, ka) = result.final_bits;
    assert!(kw < 8 || ka < 8, "bits should have moved from 8/8: {kw}/{ka}");
    assert!(result.wcr > 1.0);
    assert!(result.bitops_g > 0.0);
}

#[test]
fn finetune_scenario_roundtrip() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let tmp = std::env::temp_dir().join(format!("adaqat_it_{}", std::process::id()));
    let mut cfg = ExperimentConfig::default_for("smallcnn");
    cfg.epochs = 1;
    cfg.train_size = 256;
    cfg.test_size = 128;
    let ck_path = ensure_fp32_pretrain(&rt, &cfg, 1, &tmp).unwrap();
    assert!(ck_path.exists());
    // reuse is cached
    let again = ensure_fp32_pretrain(&rt, &cfg, 1, &tmp).unwrap();
    assert_eq!(ck_path, again);

    let ck = Checkpoint::load(&ck_path).unwrap();
    assert!(ck.meta.get("fp32").is_some());
    cfg.scenario = Scenario::Finetune { checkpoint: ck_path.clone() };
    cfg.controller = ControllerKind::Fixed { k_w: 3, k_a: 4 };
    let exp = Experiment::new(&rt, cfg).unwrap();
    let result = exp.run().unwrap();
    assert_eq!(result.final_bits, (3, 4));
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn trainer_runs_fixed_and_adaqat_identically_shaped() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let ds = synth::generate(DatasetKind::Cifar10, 256, 9, 0).into_shared();
    let test = synth::generate(DatasetKind::Cifar10, 128, 9, 1).into_shared();
    let train_loader = Loader::new(ds, rt.mm.batch, true);
    let test_loader = Loader::new(test, rt.mm.batch, false);
    let mut cfg = ExperimentConfig::default_for("smallcnn");
    cfg.epochs = 1;

    let mut state = rt.init_state(0).unwrap();
    let mut fixed = FixedController::new(4, 4);
    let r1 = train::train(&rt, &cfg, &mut fixed, &mut state, &train_loader, &test_loader)
        .unwrap();
    assert_eq!(r1.final_bits, (4, 4));
    assert!(r1.trace.is_empty(), "fixed controller never probes");

    let mut state2 = rt.init_state(0).unwrap();
    let mut ada = AdaQatController::with_defaults(8.0, 8.0, 0.15);
    let r2 = train::train(&rt, &cfg, &mut ada, &mut state2, &train_loader, &test_loader)
        .unwrap();
    assert_eq!(r1.steps, r2.steps);
    assert_eq!(r2.trace.len(), r2.steps); // probe_interval = 1
}

#[test]
fn checkpoint_save_load_roundtrip_through_runtime() {
    let rt = require_artifacts!().load_model("smallcnn").unwrap();
    let mut state = rt.init_state(10).unwrap();
    let batch = small_batch(&rt, 19);
    let s = bitwidth_scale(8);
    for _ in 0..5 {
        rt.train_step(&mut state, &batch, 0.1, s, s, false).unwrap();
    }
    let path = std::env::temp_dir().join(format!("adaqat_rt_{}.ckpt", std::process::id()));
    train::save_checkpoint(&rt, &state, adaqat::util::json::Json::Null, &path).unwrap();
    let ck = Checkpoint::load(&path).unwrap();
    let restored = rt.load_state(&ck, 0).unwrap();
    // params and bn restored exactly; loss identical
    let a = rt.probe_loss(&state, &batch, s, s).unwrap();
    let b = rt.probe_loss(&restored, &batch, s, s).unwrap();
    assert_eq!(a.loss, b.loss);
    std::fs::remove_file(path).ok();
}

//! Offline end-to-end tests for the native training backend — no AOT
//! artifacts, no PJRT, no Python. This is the closure of the whole
//! pipeline: a real gradient-descent run feeds the AdaQAT controller
//! *measured* probe losses, the controller oscillates and freezes, the
//! run exports an `AQQCKPT1` checkpoint, and the PR-2 integer kernels
//! serve it with every prediction matching the trainer's own eval
//! forward.

use std::path::PathBuf;

use adaqat::backprop::NativeBackend;
use adaqat::config::{ControllerKind, ExperimentConfig};
use adaqat::coordinator::{self, Experiment};
use adaqat::data::{synth, DatasetKind};
use adaqat::runtime::StepBackend;
use adaqat::serve::{QuantizedCheckpoint, ReferenceBackend};
use adaqat::tensor::checkpoint::Checkpoint;
use adaqat::train::schedule::CosineSchedule;

/// Small offline config: 16×16 synthetic images, one 32-wide hidden
/// layer, 16-sample batches — sized so the whole suite stays fast in
/// debug builds while still giving the controller a real loss surface.
fn native_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("native-mlp");
    cfg.model = "native-mlp".to_string();
    cfg.backend = "native".to_string();
    cfg.dataset = "cifar10".to_string();
    cfg.image_hw = 16;
    cfg.batch = 16;
    cfg.hidden = vec![32];
    cfg.train_size = 256;
    cfg.test_size = 64;
    cfg.lr = 0.01;
    cfg.epochs = 3;
    cfg
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("adaqat_native_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The acceptance path: full AdaQAT run on measured losses → freeze via
/// oscillation → export → serve through the integer kernels → every
/// prediction matches the trainer's eval forward.
#[test]
fn full_adaqat_run_exports_and_serves_identically() {
    let mut cfg = native_cfg();
    cfg.epochs = 12; // 192 steps: descent + oscillation + margin
    cfg.controller = ControllerKind::AdaQat;
    // Activations pinned at 8 (η_a = 0); weights learned. η_w is kept
    // small with a large λ: the hardware pull η·λ·k_a ≈ 0.12/step walks
    // N_w down briskly, while the small η bounds the rebound when a
    // floor probe at 1–2 bits measures a catastrophic loss — so the
    // oscillation (and the freeze point) stays in the low-bit band
    // instead of being flung high by one huge finite difference.
    cfg.init_nw = 5.0;
    cfg.init_na = 8.0;
    cfg.eta_w = 0.05;
    cfg.eta_a = 0.0;
    cfg.lambda = 0.3;
    cfg.osc_threshold = 3;
    cfg.probe_interval = 1;
    let out_dir = tmpdir("e2e");
    cfg.out_dir = Some(out_dir.clone());

    let backend = NativeBackend::from_config(&cfg).unwrap();
    let exp = Experiment::new(&backend, cfg).unwrap();
    let result = exp.run().unwrap();

    // the controller ran on measured losses and froze the weight axis
    // by oscillation (freeze picks the larger point, so k_w >= 2)
    assert!(!result.trace.is_empty(), "controller never probed");
    assert!(result.trace.iter().all(|t| t.train_loss.is_finite()));
    let (k_w, k_a) = result.final_bits;
    assert_eq!(k_a, 8, "eta_a = 0 must pin activations");
    assert!(
        (2..=8).contains(&k_w),
        "frozen k_w = {k_w} outside the expected band (N trace: {:?})",
        result.trace.iter().map(|t| t.n_w).collect::<Vec<_>>()
    );
    assert!(
        result.trace.iter().any(|t| t.osc_w >= 3),
        "weight axis should have frozen via oscillation, max osc = {:?}",
        result.trace.iter().map(|t| t.osc_w).max()
    );
    // loss moved: a real training signal, not the synthetic landscape
    let first = result.epochs.first().unwrap().train_loss;
    let last = result.epochs.last().unwrap().train_loss;
    assert!(last < first, "train loss did not improve: {first} -> {last}");

    // ---- export: the run's own checkpoint packs into AQQCKPT1
    let ck = Checkpoint::load(&out_dir.join("final.ckpt")).unwrap();
    assert!(ck.meta.get("mlp_layers").is_some(), "serving meta missing");
    let (q, report) = coordinator::export_packed(&ck, k_w).unwrap();
    assert_eq!(report.k_w, k_w);
    assert_eq!(report.quantized_tensors, 2, "fc1.w and fc2.w");
    let aqq = out_dir.join("final.aqq");
    q.save(&aqq).unwrap();

    // ---- serve: PR-2 integer kernels over the packed file
    let served = ReferenceBackend::from_packed(&QuantizedCheckpoint::load(&aqq).unwrap()).unwrap();
    let state = backend.load_state(&ck, 0).unwrap();
    let ds = synth::generate_sized(DatasetKind::Cifar10, 64, 99, 1, 16, 16);
    for i in 0..64 {
        let want = backend.predict(&state, ds.image(i), 1, k_w, k_a).unwrap()[0];
        assert_eq!(
            served.classify_one(ds.image(i)),
            want,
            "sample {i}: served prediction diverged from the trainer's eval forward"
        );
    }
    std::fs::remove_dir_all(&out_dir).ok();
}

/// Same seed ⇒ bit-identical RunResult trace (the native backend is
/// single-threaded math over a deterministic pipeline; the prefetch
/// thread changes timing, never content).
#[test]
fn same_seed_gives_identical_run_result() {
    let mut cfg = native_cfg();
    cfg.controller = ControllerKind::AdaQat;
    cfg.eta_w = 0.1;
    cfg.eta_a = 0.05;
    cfg.seed = 7;
    let run = |cfg: &ExperimentConfig| {
        let backend = NativeBackend::from_config(cfg).unwrap();
        Experiment::new(&backend, cfg.clone()).unwrap().run().unwrap()
    };
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.final_bits, b.final_bits);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.trace.len(), b.trace.len());
    for (x, y) in a.trace.iter().zip(&b.trace) {
        assert_eq!(x.step, y.step);
        assert_eq!((x.k_w, x.k_a), (y.k_w, y.k_a));
        assert_eq!(x.n_w.to_bits(), y.n_w.to_bits());
        assert_eq!(x.n_a.to_bits(), y.n_a.to_bits());
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!((x.osc_w, x.osc_a), (y.osc_w, y.osc_a));
    }
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
        assert_eq!(x.lr.to_bits(), y.lr.to_bits());
    }
    // different seed actually changes the run (the comparison above is
    // not vacuous)
    cfg.seed = 8;
    let c = run(&cfg);
    assert!(
        a.epochs[0].train_loss.to_bits() != c.epochs[0].train_loss.to_bits(),
        "seed change should change the trajectory"
    );
}

/// Regression for the epoch-LR off-by-one: `EpochRecord.lr` must be the
/// LR the epoch's *first* step trained at, not the next epoch's.
#[test]
fn epoch_record_reports_first_step_lr() {
    let mut cfg = native_cfg();
    cfg.epochs = 2;
    cfg.controller = ControllerKind::Fixed { k_w: 8, k_a: 8 };
    let backend = NativeBackend::from_config(&cfg).unwrap();
    let exp = Experiment::new(&backend, cfg.clone()).unwrap();
    let result = exp.run().unwrap();
    assert_eq!(result.epochs.len(), 2);
    let steps_per_epoch = result.steps / 2;
    assert_eq!(steps_per_epoch, cfg.train_size / cfg.batch);
    let sched = CosineSchedule::new(cfg.lr, cfg.epochs * steps_per_epoch);
    // epoch 0 starts at the schedule's step 0 — i.e. exactly cfg.lr
    assert_eq!(result.epochs[0].lr, sched.lr(0));
    assert_eq!(result.epochs[0].lr, cfg.lr);
    // epoch 1 starts at step `steps_per_epoch`, strictly lower
    assert_eq!(result.epochs[1].lr, sched.lr(steps_per_epoch));
    assert!(result.epochs[1].lr < result.epochs[0].lr);
}

/// The measured probe-loss surface behind the controller test: after a
/// little training, fewer weight bits ⇒ higher task loss, steeply so at
/// the bottom of the range — the wall the oscillation freeze relies on.
#[test]
fn measured_loss_surface_has_a_low_bit_wall() {
    let cfg = native_cfg();
    let backend = NativeBackend::from_config(&cfg).unwrap();
    let exp = Experiment::new(&backend, cfg.clone()).unwrap();
    let mut state = backend.init_state(3).unwrap();
    let batches = exp.train_loader.epoch(1);
    for _ in 0..3 {
        for batch in &batches {
            backend.train_step(&mut state, batch, 0.02, 8, 8, false).unwrap();
        }
    }
    let probe = |k_w: u32| {
        backend
            .probe_loss(&state, &batches[0], k_w, 8)
            .unwrap()
            .loss
    };
    let (l1, l2, l8) = (probe(1), probe(2), probe(8));
    assert!(l1.is_finite() && l2.is_finite() && l8.is_finite());
    assert!(
        l1 > l8 + 0.05,
        "1-bit weights should hurt a trained net: L(1)={l1} vs L(8)={l8}"
    );
    assert!(l1 > l2, "the wall should steepen toward 1 bit: L(1)={l1} vs L(2)={l2}");
}

/// The fine-tuning scenario works offline too: fp32 pretrain through
/// the shared `ensure_fp32_pretrain`, then a quantized run from it.
#[test]
fn finetune_from_native_fp32_pretrain() {
    let mut cfg = native_cfg();
    cfg.epochs = 2;
    let backend = NativeBackend::from_config(&cfg).unwrap();
    let cache = tmpdir("pretrain");
    let ck_path = coordinator::ensure_fp32_pretrain(&backend, &cfg, 2, &cache).unwrap();
    assert!(ck_path.exists());
    // same geometry ⇒ cache hit; different hidden widths ⇒ a distinct
    // cache entry, not a stale shape-mismatched checkpoint
    let again = coordinator::ensure_fp32_pretrain(&backend, &cfg, 2, &cache).unwrap();
    assert_eq!(ck_path, again);
    let mut cfg2 = native_cfg();
    cfg2.epochs = 2;
    cfg2.hidden = vec![16];
    let backend2 = NativeBackend::from_config(&cfg2).unwrap();
    let other = coordinator::ensure_fp32_pretrain(&backend2, &cfg2, 2, &cache).unwrap();
    assert_ne!(ck_path, other, "geometry must be part of the pretrain cache key");
    cfg.scenario = adaqat::config::Scenario::Finetune { checkpoint: ck_path };
    cfg.controller = ControllerKind::Fixed { k_w: 4, k_a: 8 };
    let result = Experiment::new(&backend, cfg).unwrap().run().unwrap();
    assert_eq!(result.final_bits, (4, 8));
    assert!(result.test_top1 > 0.0);
    std::fs::remove_dir_all(&cache).ok();
}
